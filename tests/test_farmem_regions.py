"""Heterogeneous far memory: regions, latency distributions, shared links.

Pins the contracts the tiered model must keep:

* a single region covering the address space is bit-identical to the flat
  model (same RNG stream, same link math, same ledger);
* ``issue_batch`` is bit-identical to the scalar ``issue()`` loop across
  region boundaries, backpressure modes, and every latency distribution;
* token streams stay aligned across the scalar and batch paths (the
  unlimited-path ``_token`` drift bug), so regions can mix backpressured
  and unlimited tiers in one model;
* ``reset_stats`` clears the queueing state (link serialization points,
  backpressure heaps) so a measured phase after a warmup starts idle;
* shared links serialize across regions, private links don't;
* the schedulers' exact-wake planning composes with regioned/bursty
  done-times (done-times are computed at issue), pinned against the
  single-step oracle;
* a mixed-tier GUPS run (local + 1 µs + 5 µs, bimodal tail) completes on
  both engines, trace-identical, with per-region request/MLP stats.
"""
import dataclasses

import numpy as np
import pytest

from repro.amu import (REGISTRY, AmuConfig, AmuSession, BimodalTail,
                       FarMemoryConfig, FarMemoryRegion, LognormalLatency,
                       UniformJitter, far_region)
from repro.core.coroutines import DeadlockError, Scheduler
from repro.core.engine import make_engine
from repro.core.farmem import FarMemoryModel


def _region(name, start, size, lat=3000.0, bw=21.3, **kw):
    return FarMemoryRegion(name, start, size, base_latency_cycles=lat,
                           bandwidth_bytes_per_cycle=bw, **kw)


def _flat_kw(**kw):
    return dict(base_latency_cycles=3000.0, bandwidth_bytes_per_cycle=21.3,
                **kw)


# =========================================================================
# Validation
# =========================================================================
def test_region_validation_rejects_bad_layouts():
    with pytest.raises(ValueError):       # overlap
        FarMemoryConfig(regions=(_region("a", 0, 100), _region("b", 50, 100)))
    with pytest.raises(ValueError):       # out of order
        FarMemoryConfig(regions=(_region("a", 100, 50), _region("b", 0, 50)))
    with pytest.raises(ValueError):       # duplicate name
        FarMemoryConfig(regions=(_region("a", 0, 50), _region("a", 50, 50)))
    with pytest.raises(ValueError):       # empty region
        FarMemoryConfig(regions=(_region("a", 0, 0),))
    with pytest.raises(ValueError):       # negative start
        FarMemoryConfig(regions=(_region("a", -8, 64),))
    with pytest.raises(ValueError):       # both randomness spellings
        FarMemoryConfig(regions=(_region(
            "a", 0, 64, jitter_frac=0.1, distribution=UniformJitter(0.1)),))
    with pytest.raises(ValueError):       # flat config, both spellings
        FarMemoryConfig(jitter_frac=0.1, distribution=UniformJitter(0.1))
    # a gap between regions is fine (unmapped addresses just can't be used)
    FarMemoryConfig(regions=(_region("a", 0, 64), _region("b", 128, 64)))


def test_routing_errors():
    cfg = FarMemoryConfig(regions=(_region("a", 0, 64), _region("b", 128, 64)))
    far = FarMemoryModel(cfg)
    with pytest.raises(ValueError):       # no address at all
        far.issue(0.0, 8)
    with pytest.raises(ValueError):       # in the gap
        far.issue(0.0, 8, 100)
    with pytest.raises(ValueError):       # past the end
        far.issue(0.0, 8, 192)
    with pytest.raises(ValueError):       # straddles a's end
        far.issue(0.0, 16, 56)
    with pytest.raises(ValueError):       # batch: one bad address poisons
        far.issue_batch(0.0, np.full(3, 8), np.array([0, 100, 128]))
    with pytest.raises(ValueError):
        far.issue_batch(0.0, np.full(2, 8), None)
    done = far.issue(0.0, 8, 128)         # valid addresses still route
    assert done > 0


def test_amu_config_accepts_region_list():
    regions = [far_region("local", 0, 4096, 0.08),
               far_region("cxl", 4096, 4096, 1.0)]
    cfg = AmuConfig(far=regions)
    assert isinstance(cfg.far, FarMemoryConfig)
    assert [r.name for r in cfg.far.regions] == ["local", "cxl"]
    assert cfg.resolve_far_config() is cfg.far
    with pytest.raises(TypeError):
        AmuConfig(far=[])
    with pytest.raises(TypeError):
        AmuConfig(far=["nope"])
    with pytest.raises(ValueError):       # far= still shadows latency knobs
        AmuConfig(far=regions, latency_us=5.0)
    # derive() re-normalizes a fresh region list
    hot = cfg.derive(far=[far_region("all", 0, 1 << 20, 5.0)])
    assert [r.name for r in hot.far.regions] == ["all"]


# =========================================================================
# Single region == flat model, bit for bit
# =========================================================================
@pytest.mark.parametrize("dist", [
    None, UniformJitter(0.2), LognormalLatency(0.7), BimodalTail(0.1, 16.0)],
    ids=["none", "uniform", "lognormal", "bimodal"])
@pytest.mark.parametrize("max_inflight", [0, 6], ids=["unlimited", "mshr6"])
def test_single_region_bit_identical_to_flat(dist, max_inflight):
    """One region covering the whole space: same seed, same draws, same
    link math — every completion time equals the flat model's."""
    flat = FarMemoryModel(FarMemoryConfig(
        **_flat_kw(max_inflight=max_inflight, distribution=dist, seed=3)))
    tier = FarMemoryModel(FarMemoryConfig(seed=3, regions=(
        _region("all", 0, 1 << 20, max_inflight=max_inflight,
                distribution=dist),)))
    rng = np.random.default_rng(11)
    now = 0.0
    for _ in range(8):
        n = int(rng.integers(1, 12))
        sizes = rng.choice([8, 64, 512], size=n)
        addrs = rng.integers(0, 1 << 10, size=n) * 8
        if rng.random() < 0.5:
            da = np.array([flat.issue(now, int(s), int(a))
                           for s, a in zip(sizes, addrs)])
            db = np.array([tier.issue(now, int(s), int(a))
                           for s, a in zip(sizes, addrs)])
        else:
            da = flat.issue_batch(now, sizes, addrs)
            db = tier.issue_batch(now, sizes, addrs)
        assert np.array_equal(da, db)
        now += float(rng.uniform(0, 4000))
    assert flat.requests == tier.requests
    assert flat.bytes_moved == tier.bytes_moved
    t_end = now + 1e6
    assert flat.avg_mlp(t_end) == tier.avg_mlp(t_end)
    assert flat.inflight_at(now) == tier.inflight_at(now)
    stats = tier.region_stats(t_end)
    assert stats["all"]["requests"] == flat.requests
    assert flat.region_stats(t_end) is None


# =========================================================================
# Scalar vs batch across region boundaries
# =========================================================================
@pytest.mark.parametrize("shared_link", [False, True],
                         ids=["private-links", "shared-link"])
def test_issue_batch_identical_to_scalar_loop_across_regions(shared_link):
    """A batch spanning tiers (different latencies, distributions, and a
    backpressured region) must be bit-identical to the scalar issue loop —
    including the cross-region link interleaving when tiers share one
    channel."""
    link = {"link": "chan"} if shared_link else {}
    regions = (
        _region("local", 0, 4096, lat=240.0, bw=64.0),
        _region("cxl", 4096, 4096, lat=3000.0,
                distribution=LognormalLatency(0.5), **link),
        _region("xswitch", 8192, 8192, lat=15000.0, max_inflight=4,
                distribution=BimodalTail(0.2, 8.0), **link),
    )
    a = FarMemoryModel(FarMemoryConfig(seed=5, regions=regions))
    b = FarMemoryModel(FarMemoryConfig(seed=5, regions=regions))
    rng = np.random.default_rng(17)
    now = 0.0
    for _ in range(10):
        n = int(rng.integers(1, 24))
        sizes = rng.choice([8, 64], size=n)
        addrs = rng.integers(0, 16384 // 8, size=n) * 8
        # straddle-proof: clamp 64B requests to their region
        addrs = np.where((sizes == 64) & (addrs % 4096 > 4032),
                         addrs - 64, addrs)
        da = np.array([a.issue(now, int(s), int(m))
                       for s, m in zip(sizes, addrs)])
        db = b.issue_batch(now, sizes, addrs)
        assert np.array_equal(da, db)
        now += float(rng.uniform(0, 8000))
    t_end = now + 1e6
    sa_stats, sb_stats = a.region_stats(t_end), b.region_stats(t_end)
    for name in sa_stats:
        # done times are bit-identical; the ledger's issue-time sum is a
        # float accumulation whose association differs between one
        # record_batch and n record() calls — MLP agrees to accumulation
        # order, not bit-for-bit
        assert sa_stats[name]["requests"] == sb_stats[name]["requests"]
        assert sa_stats[name]["bytes"] == sb_stats[name]["bytes"]
        assert sa_stats[name]["mlp"] == pytest.approx(
            sb_stats[name]["mlp"], rel=1e-9)
    for sa, sb in zip(a._regions, b._regions):
        assert sa.link.free == sb.link.free
        assert sa.token == sb.token            # S1: aligned token streams
        assert sorted(sa.inflight) == sorted(sb.inflight)


def test_token_streams_aligned_across_paths_flat():
    """Unlimited-path issue_batch must not mint tokens the scalar path
    doesn't (the `_token += n` drift): token counters stay identical, so a
    model can mix backpressured and unlimited issue histories."""
    a = FarMemoryModel(FarMemoryConfig(**_flat_kw()))
    b = FarMemoryModel(FarMemoryConfig(**_flat_kw()))
    for _ in range(3):
        sizes = np.full(7, 8)
        for s in sizes:
            a.issue(0.0, int(s))
        b.issue_batch(0.0, sizes)
    assert a._token == b._token == 0
    # backpressured mode still mints one token per request on both paths
    c = FarMemoryModel(FarMemoryConfig(**_flat_kw(max_inflight=4)))
    d = FarMemoryModel(FarMemoryConfig(**_flat_kw(max_inflight=4)))
    for s in np.full(9, 8):
        c.issue(0.0, int(s))
    d.issue_batch(0.0, np.full(9, 8))
    assert c._token == d._token == 9


# =========================================================================
# Shared channels
# =========================================================================
def test_shared_link_serializes_across_regions():
    """Two tiers on one channel contend for injection bandwidth; on
    private links the same traffic injects independently."""
    def build(shared):
        link = {"link": "chan"} if shared else {}
        return FarMemoryModel(FarMemoryConfig(regions=(
            _region("a", 0, 4096, lat=3000.0, bw=8.0, **link),
            _region("b", 4096, 4096, lat=3000.0, bw=8.0, **link))))

    shared, private = build(True), build(False)
    for far in (shared, private):
        far.issue(0.0, 4096, 0)       # 512 cycles of serialization on a
        far.issue(0.0, 8, 4096)       # lands on b
    # shared channel: b's request injects after a's 512-cycle serialization
    assert shared._regions[1].link is shared._regions[0].link
    done_shared = shared._regions[1].ledger.dones[0]
    done_private = private._regions[1].ledger.dones[0]
    assert done_shared == pytest.approx(512 + 1 + 3000.0)
    assert done_private == pytest.approx(1 + 3000.0)
    # per-region MLP ledgers stay separate even on a shared channel
    stats = shared.region_stats(4000.0)
    assert stats["a"]["requests"] == 1 and stats["b"]["requests"] == 1
    assert stats["a"]["link"] == stats["b"]["link"] == "chan"


def test_region_stats_aggregate_to_globals():
    regions = (_region("a", 0, 4096, lat=240.0),
               _region("b", 4096, 4096, lat=15000.0))
    far = FarMemoryModel(FarMemoryConfig(regions=regions))
    rng = np.random.default_rng(0)
    addrs = rng.integers(0, 1024, size=64) * 8
    far.issue_batch(0.0, np.full(64, 8), addrs)
    t_end = 40000.0
    stats = far.region_stats(t_end)
    assert stats["a"]["requests"] + stats["b"]["requests"] == 64
    assert stats["a"]["bytes"] + stats["b"]["bytes"] == far.bytes_moved == 512
    total_mlp = stats["a"]["mlp"] + stats["b"]["mlp"]
    assert total_mlp == pytest.approx(far.avg_mlp(t_end))


# =========================================================================
# reset_stats clears queueing state (prepare/execute split regression)
# =========================================================================
def test_reset_stats_clears_link_and_backpressure():
    """After a warmup phase, reset_stats must leave the device idle: the
    measured phase's completion times equal a fresh model's."""
    for regions in ((), (_region("all", 0, 1 << 16, max_inflight=4),)):
        kw = dict(regions=regions) if regions else _flat_kw(max_inflight=4)
        warmed = FarMemoryModel(FarMemoryConfig(**kw))
        fresh = FarMemoryModel(FarMemoryConfig(**kw))
        # warmup: saturate the queue and the link
        warmed.issue_batch(0.0, np.full(32, 512), np.zeros(32, np.int64))
        warmed.reset_stats()
        assert warmed.requests == 0 and warmed.bytes_moved == 0
        assert warmed.inflight_at(1e12) == 0
        sizes = np.full(12, 64)
        addrs = np.arange(12, dtype=np.int64) * 64
        da = warmed.issue_batch(0.0, sizes, addrs)
        db = fresh.issue_batch(0.0, sizes, addrs)
        assert np.array_equal(da, db)
        assert warmed.avg_mlp(1e5) == fresh.avg_mlp(1e5)


def test_session_execute_after_prepare_phase_warmup():
    """The AmuSession prepare()/execute() timing split: warmup traffic
    driven against the prepared far model (page-in DMA, cache priming)
    must not leak link occupancy into the measured execute() phase once
    reset_stats() is called."""
    kw = dict(table_words=1024, updates=256, coroutines=16)
    with AmuSession(AmuConfig(engine="batched", latency_us=1.0)) as s:
        baseline = s.run("GUPS", **kw)

    with AmuSession(AmuConfig(engine="batched", latency_us=1.0)) as s:
        s.prepare("GUPS", **kw)
        # prepare-phase warmup: page the table in over the far link
        s.far.issue_batch(0.0, np.full(64, 4096),
                          np.arange(64, dtype=np.int64) * 4096)
        assert s.far._link_free > 0
        s.far.reset_stats()
        measured = s.execute()
    assert measured == baseline

    # sanity: without the reset, the warmup's link occupancy WOULD have
    # shifted the measured phase (this is what the fix guards against)
    with AmuSession(AmuConfig(engine="batched", latency_us=1.0)) as s:
        s.prepare("GUPS", **kw)
        s.far.issue_batch(0.0, np.full(64, 4096),
                          np.arange(64, dtype=np.int64) * 4096)
        leaked = s.execute()
    assert leaked.cycles > baseline.cycles


# =========================================================================
# Exact-wake planning composes with regioned/bursty done-times
# =========================================================================
class _SingleStepScheduler(Scheduler):
    """The pre-planning idle path (regression oracle): advance to the next
    completion, one full runtime-loop turn per completion."""

    def _idle_until_completion(self):
        if not (self._waiting_count() or self._alloc_parked):
            raise DeadlockError("live tasks but none ready/waiting")
        next_done = self.engine.next_completion_time
        if next_done is None:
            if self.engine.finished_pending:
                return
            raise DeadlockError("waiting but nothing outstanding")
        self.t = max(self.t, next_done)
        self.engine.advance(self.t)


def _tier_regions(table_bytes, tail=BimodalTail(0.1, 8.0)):
    third = (table_bytes // 3) // 8 * 8
    return [far_region("local", 0, third, 0.08),
            far_region("cxl", third, third, 1.0),
            far_region("xswitch", 2 * third, table_bytes - 2 * third, 5.0,
                       distribution=tail, link="switch")]


def _scalar_run(sched_cls, far_cfg, vector=False):
    kw = dict(table_words=2048, updates=512, coroutines=64, distinct=True)
    if vector:
        kw["vector"] = True
    inst = REGISTRY["GUPS"].build(0, **kw)
    far = FarMemoryModel(far_cfg)
    eng = make_engine("scalar", inst.engine_config, far, inst.mem,
                      record_trace=True)
    sched = sched_cls(eng)
    sched.run(inst.tasks)
    eng.drain()
    assert inst.verify(eng.mem)
    return sched.summary(), eng


@pytest.mark.parametrize("vector", [False, True], ids=["scalar", "vector"])
def test_wake_planning_bit_identical_under_regions(vector):
    """Done-times are computed at issue, so exact-wake planning must stay
    bit-identical to single-stepping even when completions come from mixed
    tiers with bursty bimodal tails."""
    cfg = AmuConfig(far=_tier_regions(2048 * 8)).far
    new_sum, new_eng = _scalar_run(Scheduler, cfg, vector=vector)
    old_sum, old_eng = _scalar_run(_SingleStepScheduler,
                                   dataclasses.replace(cfg), vector=vector)
    assert new_sum == old_sum
    assert new_eng.trace == old_eng.trace
    assert new_eng.stats == old_eng.stats
    assert np.array_equal(new_eng.mem, old_eng.mem)


# =========================================================================
# Acceptance: mixed-tier GUPS on both engines, per-region stats
# =========================================================================
@pytest.mark.parametrize("vector", [False, True], ids=["scalar", "vector"])
def test_mixed_tier_gups_both_engines(vector):
    kw = dict(table_words=2048, updates=512, coroutines=64, distinct=True)
    regions = _tier_regions(2048 * 8)
    runs = {}
    for engine in ("scalar", "batched"):
        cfg = AmuConfig(engine=engine, scheduler="scalar", vector=vector,
                        far=regions)
        with AmuSession(cfg) as s:
            stats = s.run("GUPS", record_trace=True, **kw)
            runs[engine] = (stats, s.engine.trace, s.engine.mem.copy())
        assert stats.verified
        assert stats.regions is not None
        per_tier = stats.regions
        assert set(per_tier) == {"local", "cxl", "xswitch"}
        # every tier saw traffic, and the split covers all requests
        assert all(v["requests"] > 0 for v in per_tier.values())
        assert sum(v["requests"] for v in per_tier.values()) == stats.requests
        # slower tiers hold more in-flight occupancy per request
        assert per_tier["xswitch"]["mlp"] > per_tier["local"]["mlp"]
        assert stats.mlp == pytest.approx(
            sum(v["mlp"] for v in per_tier.values()))
    (st_a, tr_a, mem_a), (st_b, tr_b, mem_b) = runs["scalar"], runs["batched"]
    assert tr_a == tr_b                 # engines trace-identical under one
    assert np.array_equal(mem_a, mem_b)  # scheduler, now with regions too
    assert st_a.cycles == st_b.cycles


def test_mixed_tier_gups_batch_scheduler_end_to_end():
    """The production stack (batched engine + batch-stepped scheduler)
    drives a mixed-tier run to a verified result with region stats."""
    with AmuSession(AmuConfig(engine="batched",
                              far=_tier_regions(2048 * 8))) as s:
        stats = s.run("GUPS", table_words=2048, updates=512, coroutines=64,
                      distinct=True)
    assert stats.verified
    assert sum(v["requests"] for v in stats.regions.values()) \
        == stats.requests
