"""Software-pipelined chase ports vs their scalar oracle ports.

The request-level-parallel workloads (HJ, HT, LL, SL, Redis) carry
`vector=True` ports that run K concurrent chases per coroutine in lockstep
(AloadVec batches — the BS probe-batch pattern generalized). The scalar port
is the oracle: for every workload, K in {1, 4, 16}, and an MSHR-limited
(`max_inflight`) far memory, the pipelined port must be pinned
trace-equivalent — identical final far-memory bytes, identical far-memory
request/byte counts (each chase issues exactly the scalar port's loads),
identical engine aload/astore totals, and a passing verify() (which also
checks the side-result arrays: joined/lookups/found).

Redis runs with `distinct=True` (at most one update per key) so final bytes
are schedule-independent; HT update RMWs commute (+= delta under key locks),
so it needs no such knob. BFS parent claims race benignly across tasks (any
valid BFS tree verifies) — its vector port is covered by
tests/test_batched_engine.py, not pinned here.
"""
import numpy as np
import pytest

from repro.amu import AmuConfig, AmuSession
from repro.core.workloads import (build_hj, build_ht, build_ll, build_redis,
                                  build_sl)

CHASE_BUILDERS = {
    "HJ": lambda **kw: build_hj(0, build_keys=1024, buckets=1024, probes=384,
                                coroutines=64, **kw),
    "HT": lambda **kw: build_ht(0, n_keys=1024, buckets=512, ops=384,
                                coroutines=64, **kw),
    "LL": lambda **kw: build_ll(0, list_len=128, lookups=64, coroutines=32,
                                **kw),
    "SL": lambda **kw: build_sl(0, n_keys=512, lookups=160, coroutines=40,
                                **kw),
    "Redis": lambda **kw: build_redis(0, n_keys=1024, buckets=1024, ops=384,
                                      coroutines=64, distinct=True, **kw),
}


def _run(wl: str, max_inflight: int = 0, **kw):
    inst = CHASE_BUILDERS[wl](**kw)
    session = AmuSession(AmuConfig(engine="batched", verify=False,
                                   latency_us=1.0,
                                   max_inflight=max_inflight))
    session.run(inst)
    session.engine.getfin_all()
    session.engine.check_invariants()
    return session.engine, session.far, inst


_ref_cache = {}


def _reference(wl: str, max_inflight: int = 0):
    key = (wl, max_inflight)
    if key not in _ref_cache:
        eng, far, inst = _run(wl, max_inflight=max_inflight)
        assert inst.verify(eng.mem), f"{wl} scalar oracle port failed verify"
        _ref_cache[key] = (eng.mem.copy(), far.requests, far.bytes_moved,
                          eng.stats["aload"], eng.stats["astore"])
    return _ref_cache[key]


def _pin(wl: str, k: int, max_inflight: int = 0):
    ref_mem, ref_req, ref_bytes, ref_al, ref_as = _reference(wl, max_inflight)
    eng, far, inst = _run(wl, max_inflight=max_inflight, vector=True,
                          pipeline_k=k)
    assert inst.verify(eng.mem), f"{wl} K={k} pipelined port failed verify"
    assert np.array_equal(eng.mem, ref_mem), f"{wl} K={k} far-memory bytes"
    assert far.requests == ref_req, (wl, k, far.requests, ref_req)
    assert far.bytes_moved == ref_bytes, (wl, k)
    assert eng.stats["aload"] == ref_al, (wl, k)
    assert eng.stats["astore"] == ref_as, (wl, k)


@pytest.mark.parametrize("k", [1, 4, 16])
@pytest.mark.parametrize("wl", sorted(CHASE_BUILDERS))
def test_pipelined_port_pinned_to_scalar(wl, k):
    _pin(wl, k)


@pytest.mark.parametrize("wl", sorted(CHASE_BUILDERS))
def test_pipelined_port_pinned_under_max_inflight(wl):
    """K=16 under device-side backpressure (MSHR-limited far memory): the
    completion-coupled admission path must not perturb the pinning."""
    _pin(wl, 16, max_inflight=12)


def test_pipelined_port_distinct_keys_per_batch():
    """Ops on the same key never share a pipeline batch: the HT update RMW
    chain must serialize per key, so the final value is the exact sum of
    deltas even when one hot key dominates (hot_frac stresses this)."""
    inst = CHASE_BUILDERS["HT"](vector=True, pipeline_k=16)
    with AmuSession(AmuConfig(engine="batched", latency_us=2.0)) as s:
        assert s.run(inst).verified
