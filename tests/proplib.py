"""Property-testing front-end: real `hypothesis` when installed, otherwise a
seeded-random fallback with the same surface (`given`, `settings`, `st`).

The fallback draws a fixed number of examples from a deterministic RNG per
test, so property tests still run (with less shrinking power) on machines
without the dev dependencies — `pip install -r requirements-dev.txt` gets
the real engine back.
"""
from __future__ import annotations

import functools
import inspect

try:
    from hypothesis import given, settings  # noqa: F401 (re-export)
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 25
    _FALLBACK_SEED = 0x5EED

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _St:
        """Seeded stand-ins for the `strategies` functions the tests use."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.integers(0, len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _St()

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kw):
                rng = np.random.default_rng(_FALLBACK_SEED)
                for _ in range(_FALLBACK_EXAMPLES):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kw)
            # strategy-drawn params must not look like pytest fixtures
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco

    def settings(**_kw):
        return lambda fn: fn
