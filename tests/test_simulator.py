"""Paper-claims reproduction tests: the calibrated model must reproduce the
headline numbers within stated tolerances, and every AMU workload port must
produce bitwise-correct results against its numpy oracle.

AMU configs run on the batched engine + batch-stepped scheduler (the
production path for sweeps); tests/test_batched_engine.py pins it to the
scalar oracle, so the claims hold for both."""
import numpy as np
import pytest

from repro.amu import REGISTRY
from repro.core import simulator as sim

# request-level workloads (open-loop arrivals) are covered by
# tests/test_serving.py; the throughput sweeps here exclude them
WORKLOADS = [n for n, d in REGISTRY.items() if not d.request_level]
ENGINE = "batched"


def run(wl, config, latency_us, **kw):
    if config.startswith("amu"):
        kw.setdefault("engine", ENGINE)
    return sim.run(wl, config, latency_us, **kw)


@pytest.mark.parametrize("wl", WORKLOADS)
def test_amu_workloads_verify(wl):
    out = run(wl, "amu", 1.0)
    assert out["verified"], f"{wl} produced wrong far-memory contents"


def test_table4_gups_baseline_curve():
    """Table 4 CXL row for GUPS: [1.00 1.38 2.54 4.40 8.21 19.83]."""
    paper = {0.1: 1.00, 0.2: 1.38, 0.5: 2.54, 1.0: 4.40, 2.0: 8.21,
             5.0: 19.83}
    b0 = run("GUPS", "baseline", 0.1)["us"]
    for lat, want in paper.items():
        got = run("GUPS", "baseline", lat)["us"] / b0
        assert abs(got - want) / want < 0.10, (lat, got, want)


def test_table4_gups_amu_flat():
    """AMU row stays ~flat (0.96..1.03 relative) across 50x latency."""
    b0 = run("GUPS", "baseline", 0.1)["us"]
    rel = [run("GUPS", "amu", lat, verify=False)["us"] / b0
           for lat in (0.1, 0.5, 1.0, 2.0, 5.0)]
    assert 0.85 < min(rel) and max(rel) < 1.35, rel


def test_headline_geomean_speedup():
    """Abstract: 2.42x average speedup @1us (ours within ~1.5x band)."""
    sp = []
    for wl in WORKLOADS:
        b = run(wl, "baseline", 1.0)["us"]
        a = run(wl, "amu", 1.0, verify=False)["us"]
        sp.append(b / a)
    geo = float(np.exp(np.mean(np.log(sp))))
    assert 1.8 < geo < 4.5, geo


def test_headline_gups_5us():
    """Abstract: 26.86x GUPS speedup @5us with >130 in flight (LLVM port)."""
    b5 = run("GUPS", "baseline", 5.0)["us"]
    l5 = run("GUPS", "amu-llvm", 5.0, verify=False)
    speedup = b5 / l5["us"]
    assert 18 < speedup < 35, speedup
    assert l5["mlp"] > 120, l5["mlp"]


def test_amu_latency_insensitive_vs_baseline():
    """Fig 8's core claim: AMU execution time is ~flat in latency while the
    baseline degrades linearly, for every random-access workload."""
    for wl in ("GUPS", "BS", "HT", "Redis"):
        a01 = run(wl, "amu", 0.1, verify=False)["us"]
        a5 = run(wl, "amu", 5.0, verify=False)["us"]
        b01 = run(wl, "baseline", 0.1)["us"]
        b5 = run(wl, "baseline", 5.0)["us"]
        assert a5 / a01 < 6.0, (wl, a5 / a01)        # AMU: mild growth
        assert b5 / b01 > 10.0, (wl, b5 / b01)       # baseline: ~linear


def test_mlp_grows_with_latency():
    """Fig 9: AMU MLP scales up as latency grows."""
    for wl in ("GUPS", "BS"):
        m1 = run(wl, "amu", 0.5, verify=False)["mlp"]
        m5 = run(wl, "amu", 5.0, verify=False)["mlp"]
        assert m5 > 1.5 * m1, (wl, m1, m5)


def test_amu_beats_dma_mode():
    """Fig 8: in-core AMU beats the external-engine (DMA-mode) ablation."""
    for wl in ("GUPS", "HJ", "Redis"):
        a = run(wl, "amu", 1.0, verify=False)["us"]
        d = run(wl, "amu-dma", 1.0, verify=False)["us"]
        assert d > 1.2 * a, (wl, a, d)


def test_ipc_improves():
    """Fig 10: AMU IPC >> baseline IPC at far-memory latencies."""
    for wl in ("GUPS", "HT"):
        a = run(wl, "amu", 1.0, verify=False)["ipc"]
        b = run(wl, "baseline", 1.0)["ipc"]
        assert a > 3 * b, (wl, a, b)


def test_disambiguation_overhead_bounded_and_declining():
    """Table 5: HJ ~5% flat-ish; HT declines as latency grows."""
    hj = [run("HJ", "amu", L, verify=False)["disamb_frac"]
          for L in (0.1, 1.0, 5.0)]
    assert all(0.01 < f < 0.12 for f in hj), hj
    ht01 = run("HT", "amu", 0.1, verify=False)["disamb_frac"]
    ht5 = run("HT", "amu", 5.0, verify=False)["disamb_frac"]
    assert ht5 < 0.5 * ht01, (ht01, ht5)


def test_cxl_ideal_between_baseline_and_amu_random():
    """CXL-Ideal (max MSHRs + BOP) helps but can't reach AMU on random
    access at high latency (the paper's motivating gap)."""
    b = run("GUPS", "baseline", 5.0)["us"]
    c = run("GUPS", "cxl-ideal", 5.0)["us"]
    a = run("GUPS", "amu", 5.0, verify=False)["us"]
    assert c <= b and a < c, (b, c, a)
