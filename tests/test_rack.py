"""Rack-scale arbitration: determinism, identity and contention accounting.

The contract under test (TESTING.md "Rack determinism contract"):

* ``cores=1`` rack runs are **bit-identical** to the plain ``AmuSession``
  — same trace, engine stats, memory/SPM images, far-model RNG bitstream
  positions and ``RunStats`` — across both engines and both scheduler
  kinds (the arbiter degenerates to literally the ``Scheduler.run`` loop).
* N-core runs are a pure function of (config, seed): the global-clock
  arbiter (smallest ``sched.t`` first, ties to the lowest core index)
  makes the merged command stream over the ONE shared far model
  reproducible bit-for-bit.
* Per-core attribution is conservative: the arbiter's per-core
  request/byte/fault splits sum to the shared device's global counters,
  and per-link ``link_busy`` attribution sums to the independently
  computable serialized-cycle totals (Σ region bytes / link bandwidth).
"""
import numpy as np
import pytest

from repro.amu import (AmuConfig, AmuSession, RackSession, far_region,
                       FaultModel, RetryPolicy)
from repro.amu.session import _core_seeds, _jain_fairness
from repro.core.farmem import BimodalTail

GUPS_KW = dict(table_words=2048, updates=512, coroutines=64, distinct=True)


def _tier_regions(table_bytes, faults=False):
    third = (table_bytes // 3) // 8 * 8
    fm = FaultModel(error_prob=0.02) if faults else None
    return [far_region("local", 0, third, 0.08),
            far_region("cxl", third, third, 1.0, link="switch",
                       distribution=BimodalTail(0.1, 8.0), faults=fm),
            far_region("xswitch", 2 * third, table_bytes - 2 * third, 5.0,
                       link="switch")]


def _far_rng_states(far):
    """Every RNG bitstream position in the far model (flat + per-region +
    fault streams) — the strictest identity witness short of the trace."""
    states = [far._rng.bit_generator.state["state"]]
    if far._fault_rng is not None:
        states.append(far._fault_rng.bit_generator.state["state"])
    for st in far._regions or ():
        states.append(st.rng.bit_generator.state["state"])
        if st.fault_rng is not None:
            states.append(st.fault_rng.bit_generator.state["state"])
    return states


def _capture_single(cfg, wl, **build_kw):
    with AmuSession(cfg) as s:
        stats = s.run(wl, record_trace=True, **build_kw)
        return (stats.to_dict(), list(s.engine.trace), dict(s.engine.stats),
                s.engine.mem.copy(), s.engine.spm.copy(),
                _far_rng_states(s.far), s.scheduler.summary())


def _capture_rack_core0(cfg, wl, **build_kw):
    with RackSession(cfg) as r:
        rs = r.run(wl, record_trace=True, **build_kw)
        eng = r.engines[0]
        return (rs.cores[0].to_dict(), list(eng.trace), dict(eng.stats),
                eng.mem.copy(), eng.spm.copy(), _far_rng_states(r.far),
                r.schedulers[0].summary())


# =========================================================================
# cores=1 identity: a one-core rack IS the plain session, bit for bit
# =========================================================================
@pytest.mark.parametrize("engine,scheduler", [
    ("scalar", "auto"),        # oracle engine, per-command scalar loop
    ("batched", "batched"),    # per-command batched loop
    ("batched", "auto"),       # epoch-fused loop
], ids=["scalar+percmd", "batched+percmd", "batched+fused"])
def test_cores1_bit_identical_to_amusession(engine, scheduler):
    cfg = AmuConfig(engine=engine, scheduler=scheduler)
    a = _capture_single(cfg, "GUPS", **GUPS_KW)
    b = _capture_rack_core0(cfg.derive(cores=1), "GUPS", **GUPS_KW)
    for got, want in zip(b, a):
        if isinstance(want, np.ndarray):
            assert np.array_equal(got, want)
        else:
            assert got == want


def test_cores1_identity_tiered_faulty_retry():
    """Identity must survive the full fault plane: tiered far memory with
    a shared link, fault draws, retry/backoff and timeouts."""
    cfg = AmuConfig(far=_tier_regions(2048 * 8, faults=True),
                    retry=RetryPolicy(max_retries=2, backoff=128.0))
    a = _capture_single(cfg, "GUPS", **GUPS_KW)
    b = _capture_rack_core0(cfg.derive(cores=1), "GUPS", **GUPS_KW)
    for got, want in zip(b, a):
        if isinstance(want, np.ndarray):
            assert np.array_equal(got, want)
        else:
            assert got == want


def test_cores1_rackstats_wraps_runstats():
    with RackSession(AmuConfig()) as r:
        rs = r.run("GUPS", **GUPS_KW)
    assert rs.n_cores == 1
    assert rs.fairness == 1.0
    assert rs.requests == rs.cores[0].requests
    assert rs.bytes == rs.cores[0].bytes
    assert rs.core_gups[0] == pytest.approx(rs.aggregate_gups)
    assert rs.cores[0].regions is None          # flat model
    assert set(rs.link_occupancy) == {"far"}


# =========================================================================
# N-core determinism: same (config, seed) => identical everything
# =========================================================================
def _capture_rack(cfg, ports, **build_kw):
    with RackSession(cfg) as r:
        rs = r.run(ports, record_trace=True, **build_kw)
        return rs, [list(e.trace) for e in r.engines], \
            [e.mem.copy() for e in r.engines]


@pytest.mark.parametrize("scheduler", ["batched", "auto"],
                         ids=["percmd", "fused"])
def test_ncore_run_is_deterministic(scheduler):
    cfg = AmuConfig(cores=4, scheduler=scheduler,
                    far=_tier_regions(2048 * 8))
    rs_a, traces_a, mems_a = _capture_rack(cfg, "GUPS", **GUPS_KW)
    rs_b, traces_b, mems_b = _capture_rack(cfg, "GUPS", **GUPS_KW)
    assert traces_a == traces_b                 # per-core issue/fin traces
    assert rs_a == rs_b                         # full RackStats identity
    for ma, mb in zip(mems_a, mems_b):
        assert np.array_equal(ma, mb)


def test_cores_get_independent_streams():
    """Spawned per-core seeds: core 0 keeps the config seed verbatim,
    later cores get distinct seeds, and the cores issue distinct address
    streams (different traces) while every core still verifies."""
    assert _core_seeds(0, 1) == [0]
    s4 = _core_seeds(0, 4)
    assert s4[0] == 0 and len(set(s4)) == 4
    assert _core_seeds(0, 4) == s4              # deterministic
    rs, traces, _ = _capture_rack(AmuConfig(cores=3), "GUPS", **GUPS_KW)
    assert rs.verified is True
    assert traces[0] != traces[1] and traces[1] != traces[2]


def test_attribution_is_conservative():
    """Per-core request/byte attribution sums exactly to the shared far
    model's global counters."""
    cfg = AmuConfig(cores=4, far=_tier_regions(2048 * 8))
    with RackSession(cfg) as r:
        rs = r.run("GUPS", **GUPS_KW)
    assert sum(c.requests for c in rs.cores) == rs.requests
    assert sum(c.bytes for c in rs.cores) == rs.bytes
    assert all(c.regions is None for c in rs.cores)
    assert set(rs.regions) == {"local", "cxl", "xswitch"}
    assert rs.cycles == pytest.approx(max(c.cycles for c in rs.cores))


# =========================================================================
# Contention accounting: link_busy sums == independently derived totals
# =========================================================================
def _expected_link_busy(far):
    """Σ over regions-on-link of bytes / bandwidth — an independent
    derivation of what the per-issue ``_charge_link`` calls accumulated."""
    if far._regions is None:
        return {"far": far.bytes_moved
                / far.config.bandwidth_bytes_per_cycle}
    out = {}
    for st in far._regions:
        link = st.region.link or st.region.name
        out[link] = out.get(link, 0.0) \
            + st.bytes_moved / st.region.bandwidth_bytes_per_cycle
    return out


@pytest.mark.parametrize("cores", [1, 4])
@pytest.mark.parametrize("far_kind", ["flat", "tiered"])
def test_link_busy_matches_region_byte_totals(cores, far_kind):
    far = _tier_regions(2048 * 8) if far_kind == "tiered" else None
    cfg = AmuConfig(cores=cores, far=far)
    with RackSession(cfg) as r:
        rs = r.run("GUPS", **GUPS_KW)
        expected = _expected_link_busy(r.far)
    assert set(rs.link_occupancy) == set(expected)
    for link, want in expected.items():
        got = rs.link_occupancy[link]
        assert sum(got["by_client"].values()) \
            == pytest.approx(got["busy_cycles"])
        assert got["busy_cycles"] == pytest.approx(want, rel=1e-9)
        assert set(got["by_client"]) <= set(range(cores))


def test_shared_link_contention_slows_cores_down():
    """Four cores over one shared switch link: the rack makespan must
    exceed one core's solo run (the contention is real), yet every core
    still verifies against its oracle."""
    solo = AmuConfig(far=_tier_regions(2048 * 8))
    with RackSession(solo) as r:
        rs1 = r.run("GUPS", **GUPS_KW)
    with RackSession(solo.derive(cores=4)) as r:
        rs4 = r.run("GUPS", **GUPS_KW)
    assert rs4.verified is True
    assert rs4.cycles > rs1.cycles
    occ1 = rs1.link_occupancy["switch"]["occupancy"]
    occ4 = rs4.link_occupancy["switch"]["occupancy"]
    assert occ4 > occ1                  # the shared channel got busier


# =========================================================================
# Fairness + aggregates
# =========================================================================
def test_jain_fairness_index():
    assert _jain_fairness([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert _jain_fairness([1.0, 0.0, 0.0]) == pytest.approx(1.0 / 3.0)
    assert _jain_fairness([]) == 1.0            # degenerate: no cores
    assert 0.0 < _jain_fairness([3.0, 1.0]) < 1.0


def test_homogeneous_rack_is_fair():
    with RackSession(AmuConfig(cores=4)) as r:
        rs = r.run("GUPS", **GUPS_KW)
    assert rs.fairness > 0.9
    assert rs.aggregate_gups == pytest.approx(
        sum(c.units for c in rs.cores) / (rs.us * 1e3))


def test_mixed_colocation_runs_and_attributes():
    """Heterogeneous rack: GUPS colocated with the paged-KV serving port
    over one shared flat far memory — both verify, attribution still sums,
    and the serving core keeps its request-latency percentiles."""
    from repro.amu import REGISTRY
    ports = [REGISTRY.build("GUPS", 0, **GUPS_KW),
             REGISTRY.build("paged_kv_serve", 1, requests=64, coroutines=16)]
    with RackSession(AmuConfig(cores=2)) as r:
        rs = r.run(ports)
    assert rs.verified is True
    assert rs.cores[0].workload == "GUPS"
    assert rs.cores[1].workload == "paged_kv_serve"
    assert rs.cores[1].req_p99_us is not None
    assert sum(c.requests for c in rs.cores) == rs.requests


# =========================================================================
# Surface validation
# =========================================================================
def test_config_rejects_bad_cores():
    for bad in (0, -1, 1.5, True, "4"):
        with pytest.raises((ValueError, TypeError)):
            AmuConfig(cores=bad)


def test_rack_rejects_port_list_length_mismatch():
    with RackSession(AmuConfig(cores=3)) as r:
        with pytest.raises(ValueError, match="3 ports|2 ports"):
            r.run(["GUPS", "GUPS"])


def test_rack_rejects_single_prebuilt_port_fanout():
    from repro.amu import REGISTRY
    inst = REGISTRY.build("GUPS", 0, **GUPS_KW)
    with RackSession(AmuConfig(cores=2)) as r:
        with pytest.raises(ValueError, match="prebuilt"):
            r.run(inst)


def test_rack_rejects_frontier_ports():
    with RackSession(AmuConfig(cores=2)) as r:
        with pytest.raises(NotImplementedError, match="frontier"):
            r.run("BFS")


def test_rack_execute_requires_prepare():
    with RackSession(AmuConfig()) as r:
        with pytest.raises(RuntimeError, match="prepare"):
            r.execute()
