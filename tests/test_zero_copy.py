"""Zero-copy SPM data-plane property suite.

The contract (documented in TESTING.md):

* ``spm_read`` / the :class:`SpmRead` command return a READ-ONLY numpy view
  **aliasing live SPM** — not a snapshot. The view observes every subsequent
  ``spm_write`` and every DMA retirement that lands in its range.
* Mutation only goes through ``spm_write`` (bytes or C-contiguous ndarray);
  writing through a view raises.
* The scalar oracle engine polices racy accesses: a synchronous SPM access
  overlapping the destination of an in-flight LOAD raises AssertionError
  (store payloads are captured at issue, so stores never conflict).

Everything runs under both engines and both memory models.

`hypothesis` optional — tests/proplib.py falls back to seeded-random
example generation.
"""
import numpy as np
import pytest
from proplib import given, settings, st

from repro.configs.base import EngineConfig
from repro.core.coroutines import (Aload, AloadNoWait, AwaitRid,
                                   BatchScheduler, Scheduler, SpmRead,
                                   SpmWrite)
from repro.core.engine import SpmOverflow, make_engine
from repro.core.farmem import FarMemoryConfig, FarMemoryModel, InstantMemory

ENGINES = ["scalar", "batched"]
MEMS = ["instant", "timed"]


def _engine(kind: str, mem_kind: str, qlen: int = 32, granularity: int = 8):
    far = InstantMemory() if mem_kind == "instant" else FarMemoryModel(
        FarMemoryConfig.from_latency_us(1.0))
    return make_engine(kind, EngineConfig(queue_length=qlen,
                                          granularity=granularity), far)


# =========================================================================
# Engine-level view semantics
# =========================================================================
@pytest.mark.parametrize("kind", ENGINES)
@pytest.mark.parametrize("mem_kind", MEMS)
def test_spm_read_returns_live_readonly_view(kind, mem_kind):
    eng = _engine(kind, mem_kind)
    eng.spm_write(0, bytes(range(16)))
    view = eng.spm_read(0, 16)
    assert isinstance(view, np.ndarray) and view.dtype == np.uint8
    assert not view.flags.writeable
    assert view.base is eng.spm                   # zero-copy: aliases SPM
    assert bytes(view) == bytes(range(16))
    with pytest.raises(ValueError):
        view[0] = 99                              # mutation must go via write
    # live alias: a later spm_write is observed by the existing view
    eng.spm_write(4, bytes([200] * 4))
    assert view[4] == 200 and view[3] == 3


@pytest.mark.parametrize("kind", ENGINES)
@pytest.mark.parametrize("mem_kind", MEMS)
def test_view_observes_dma_retirement(kind, mem_kind):
    """A DMA landing inside a view's range after the view was taken is
    visible through the view (documented live-alias semantics)."""
    eng = _engine(kind, mem_kind)
    eng.mem[100:108] = np.arange(50, 58, dtype=np.uint8)
    view = eng.spm_read(0, 8)
    assert bytes(view) == bytes(8)
    eng.aload(0, 100, 8)
    eng.drain()
    eng.getfin_all()
    assert bytes(view) == bytes(range(50, 58))


@pytest.mark.parametrize("kind", ENGINES)
def test_spm_write_ndarray_equals_bytes(kind):
    """spm_write accepts bytes or any C-contiguous ndarray; both land the
    same bytes (ports can skip the .tobytes() round trip)."""
    a, b = _engine(kind, "instant"), _engine(kind, "instant")
    payload = np.arange(8, dtype=np.float64) * 1.5
    a.spm_write(16, payload.tobytes())
    b.spm_write(16, payload)
    assert np.array_equal(a.spm, b.spm)
    got = b.spm_read(16, 64).view(np.float64)
    assert np.array_equal(got, payload)


@pytest.mark.parametrize("kind", ENGINES)
def test_spm_bounds_fail_loudly(kind):
    eng = _engine(kind, "instant")
    with pytest.raises(SpmOverflow):
        eng.spm_read(eng.spm_data_bytes - 4, 8)
    with pytest.raises(SpmOverflow):
        eng.spm_read(-8, 8)
    with pytest.raises(SpmOverflow):
        eng.spm_write(eng.spm_data_bytes - 4, bytes(8))
    with pytest.raises(SpmOverflow):
        eng.spm_write(-8, bytes(8))


# =========================================================================
# Oracle race policing (the scalar engine fails loudly on view races)
# =========================================================================
@pytest.mark.parametrize("mem_kind", ["timed"])
def test_oracle_asserts_on_read_racing_inflight_load(mem_kind):
    eng = _engine("scalar", mem_kind)
    eng.aload(8, 512, 8)                    # in flight (timed memory)
    with pytest.raises(AssertionError, match="races in-flight aload"):
        eng.spm_read(8, 8)
    with pytest.raises(AssertionError, match="races in-flight aload"):
        eng.spm_read(0, 16)                 # partial overlap
    with pytest.raises(AssertionError, match="races in-flight aload"):
        eng.spm_write(12, bytes(8))         # write into the landing zone
    eng.spm_read(16, 8)                     # adjacent, disjoint: fine
    eng.spm_write(0, bytes(8))
    eng.drain()
    eng.getfin_all()
    eng.spm_read(8, 8)                      # retired: fine now


def test_oracle_allows_access_over_inflight_store():
    """Store payloads are captured at issue — reading or rewriting the
    source region while the store is in flight is NOT a race."""
    eng = _engine("scalar", "timed")
    eng.spm_write(0, bytes(range(8)))
    eng.astore(0, 512, 8)
    assert bytes(eng.spm_read(0, 8)) == bytes(range(8))
    eng.spm_write(0, bytes([7] * 8))        # overwrite source: still fine
    eng.drain()
    eng.getfin_all()
    assert bytes(eng.mem[512:520]) == bytes(range(8))   # captured payload


# =========================================================================
# Scheduler-level: views handed to coroutines follow the same contract
# =========================================================================
@pytest.mark.parametrize("kind,sched_cls", [("scalar", Scheduler),
                                            ("batched", BatchScheduler)])
@pytest.mark.parametrize("mem_kind", MEMS)
def test_task_view_sees_subsequent_spm_write(kind, sched_cls, mem_kind):
    eng = _engine(kind, mem_kind)
    seen = {}

    def task():
        yield SpmWrite(0, bytes(range(8)))
        view = yield SpmRead(0, 8)
        before = bytes(view)
        yield SpmWrite(0, bytes([9] * 8))   # view must observe this
        seen["before"], seen["after"] = before, bytes(view)

    sched_cls(eng).run([task()])
    assert seen["before"] == bytes(range(8))
    assert seen["after"] == bytes([9] * 8)


@pytest.mark.parametrize("kind,sched_cls", [("scalar", Scheduler),
                                            ("batched", BatchScheduler)])
@pytest.mark.parametrize("mem_kind", MEMS)
def test_task_view_sees_awaited_dma(kind, sched_cls, mem_kind):
    """An awaited aload landing in a previously-taken view's range is
    observed through the view once the task resumes."""
    eng = _engine(kind, mem_kind)
    eng.mem[64:72] = np.arange(30, 38, dtype=np.uint8)
    seen = {}

    def task():
        view = yield SpmRead(0, 8)
        assert bytes(view) == bytes(8)
        tok = yield AloadNoWait(0, 64, 8)
        yield AwaitRid(tok)                 # DMA retired before resume
        seen["after"] = bytes(view)

    sched_cls(eng).run([task()])
    assert seen["after"] == bytes(range(30, 38))


@pytest.mark.parametrize("kind,sched_cls", [("scalar", Scheduler),
                                            ("batched", BatchScheduler)])
def test_snapshot_copy_isolates(kind, sched_cls):
    """The documented escape hatch: .copy() detaches a snapshot from later
    overwrites (what the SL port's double-buffering avoids paying)."""
    eng = _engine(kind, "instant")
    seen = {}

    def task():
        yield SpmWrite(0, bytes(range(8)))
        view = yield SpmRead(0, 8)
        snap = view.copy()
        yield Aload(0, 256, 8)              # overwrites the viewed range
        seen["view"], seen["snap"] = bytes(view), bytes(snap)

    sched_cls(eng).run([task()])
    assert seen["view"] == bytes(eng.mem[256:264])
    assert seen["snap"] == bytes(range(8))


# =========================================================================
# Property: random interleavings — views always reflect the live SPM state
# =========================================================================
@given(ops=st.lists(st.sampled_from(["write", "load", "read"]),
                    min_size=1, max_size=60),
       seed=st.integers(0, 1 << 16))
@settings(max_examples=25, deadline=None)
def test_view_coherence_property(ops, seed):
    """Both engines: any interleaving of spm_write / retired aloads keeps
    every previously-taken view bit-identical to the live SPM range it
    aliases, and the engines agree byte-for-byte."""
    rng = np.random.default_rng(seed)
    engines = [_engine(k, "timed", qlen=16) for k in ENGINES]
    fill = rng.integers(0, 256, 1024).astype(np.uint8)
    for eng in engines:
        eng.mem[:1024] = fill
    views = []
    for op in ops:
        spm = int(rng.integers(0, 56)) * 8
        if op == "write":
            data = bytes(rng.integers(0, 256, 8).astype(np.uint8))
            for eng in engines:
                eng.spm_write(spm, data)
        elif op == "load":
            addr = int(rng.integers(0, 120)) * 8
            for eng in engines:
                eng.aload(spm, addr, 8)
                eng.drain()                  # retire before the next access
                eng.getfin_all()
        else:
            views.append((spm, [eng.spm_read(spm, 8) for eng in engines]))
        for spm_v, pair in views:
            for eng, v in zip(engines, pair):
                assert bytes(v) == bytes(eng.spm[spm_v:spm_v + 8])
        assert np.array_equal(engines[0].spm, engines[1].spm)
