"""Substrate tests: data pipeline determinism, optimizer math, checkpoint
roundtrip + elastic restore, fault-tolerant supervisor, straggler monitor,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import PrefetchingLoader, input_specs, synthetic_batch
from repro.models import lm
from repro.optim import adamw
from repro.runtime.ft import StepMonitor, TrainSupervisor
from repro.runtime import steps as steps_mod


# ------------------------------------------------------------------ data
def test_synthetic_batch_deterministic_and_restart_safe():
    cfg = configs.get_smoke_config("qwen2.5-3b")
    shape = configs.ShapeConfig("t", 16, 4, "train")
    a = synthetic_batch(cfg, shape, step=7, seed=3)
    b = synthetic_batch(cfg, shape, step=7, seed=3)
    c = synthetic_batch(cfg, shape, step=8, seed=3)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetching_loader_order_and_shutdown():
    cfg = configs.get_smoke_config("qwen2.5-3b")
    shape = configs.ShapeConfig("t", 16, 4, "train")
    loader = PrefetchingLoader(cfg, shape, seed=0, depth=2, start_step=5)
    steps = [next(loader)[0] for _ in range(4)]
    loader.close()
    assert steps == [5, 6, 7, 8]


def test_input_specs_cover_all_cells():
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for name, shape in configs.SHAPES.items():
            ok, _ = configs.shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, name)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


# ------------------------------------------------------------------ optim
def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(learning_rate=0.1, warmup_steps=1,
                            total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 0.05


def test_grad_clip():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-3)


def test_int8_compression_error_feedback():
    """Quantization error must shrink under error feedback (residual carried)."""
    rng = np.random.default_rng(0)
    g = jnp.array(rng.standard_normal(512), jnp.float32)
    q, scale = adamw.quantize_int8(g)
    deq = adamw.dequantize_int8(q, scale)
    rel = float(jnp.linalg.norm(g - deq) / jnp.linalg.norm(g))
    assert rel < 0.02
    # error feedback: residual + next grad -> average converges to truth
    err = g - deq
    q2, s2 = adamw.quantize_int8(g + err)
    deq2 = adamw.dequantize_int8(q2, s2)
    rel2 = float(jnp.linalg.norm((deq + deq2) / 2 - g)
                 / jnp.linalg.norm(g))
    assert rel2 < rel


def test_bf16_moments():
    params = {"w": jnp.ones((8, 8))}
    st = adamw.init_state(params, moment_dtype=jnp.bfloat16)
    assert st["m"]["w"].dtype == jnp.bfloat16
    cfg = adamw.AdamWConfig()
    g = {"w": jnp.full((8, 8), 0.1)}
    p2, st2, _ = adamw.apply_updates(cfg, params, g, st)
    assert st2["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_prune(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((2,)), jnp.zeros((5,), jnp.int32)]}
    store.save(10, tree, blocking=True, extra={"step": 10})
    store.save(20, tree, blocking=False, extra={"step": 20})
    store.wait()
    assert store.latest_step() == 20
    restored, extra = store.restore(20, tree)
    assert extra["step"] == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    store.prune(keep=1)
    assert store.latest_step() == 20
    assert not os.path.exists(os.path.join(str(tmp_path), "step_00000010"))


def test_supervisor_survives_failure_and_replays_identically(tmp_path):
    """Kill training mid-run; the restarted run must converge to the same
    final state as an uninterrupted one (deterministic data + checkpoint)."""
    cfg = configs.get_smoke_config("qwen2.5-3b")
    shape = configs.ShapeConfig("t", 16, 4, "train")
    par = configs.ParallelConfig(remat="none")
    opt_cfg = adamw.AdamWConfig(total_steps=12)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init_state(params)
    step_fn = jax.jit(steps_mod.make_train_step(cfg, par, opt_cfg))

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in synthetic_batch(cfg, shape, step).items()}

    def run(fail_at, d):
        store = CheckpointStore(str(tmp_path / d))
        sup = TrainSupervisor(store, checkpoint_every=4)
        state = sup.run({"params": params, "opt_state": opt_state, "step": 0},
                        step_fn, batch_fn, total_steps=10, fail_at=fail_at)
        return state, sup

    clean, _ = run(None, "clean")
    failed, sup = run(6, "failed")
    assert sup.restarts == 1
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(failed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_straggler_monitor():
    mon = StepMonitor(warmup=2, straggler_factor=2.0)
    flagged = []
    mon.on_straggler = lambda s, d, e: flagged.append(s)
    for s in range(6):
        mon.record(s, 0.10)
    assert mon.record(6, 0.35) is True
    assert flagged == [6]
    # ewma not polluted by the straggler sample
    assert abs(mon.ewma - 0.10) < 0.02


# ---------------------------------------------------------------- offload
def test_offloaded_kv_cache_roundtrip_and_prefetch():
    import jax.numpy as jnp

    from repro.runtime.offload import OffloadedKVCache

    L = 6
    cache = OffloadedKVCache(num_layers=L, window=2)
    rng = np.random.default_rng(0)
    pages = [rng.standard_normal((4, 8)).astype(np.float32) for _ in range(L)]
    for i, p in enumerate(pages):
        cache.host_put(i, p)
    # decode walk: fetch each layer, update it, let the window recycle
    cache.prefetch(0)
    for i in range(L):
        page = cache.fetch(i)
        np.testing.assert_array_equal(np.asarray(page), pages[i])
        cache.update(i, jnp.asarray(page) + 1.0)
    cache.flush()
    for i in range(L):
        np.testing.assert_allclose(cache._host[i], pages[i] + 1.0)
    # issue-ahead actually happened: layers 1..L-1 were prefetched
    assert cache.stats["prefetch_issued"] >= L - 1
    assert cache.stats["prefetch_hits"] >= L - 1
    assert cache.stats["writebacks"] == L
    cache.close()


def test_offloaded_kv_cache_clean_pages_skip_writeback():
    import jax.numpy as jnp

    from repro.runtime.offload import OffloadedKVCache

    L = 8
    cache = OffloadedKVCache(num_layers=L, window=2)
    rng = np.random.default_rng(1)
    pages = [rng.standard_normal((4, 8)).astype(np.float32) for _ in range(L)]
    for i, p in enumerate(pages):
        cache.host_put(i, p)
    dirty = {1, 4, 5}
    for i in range(L):
        page = cache.fetch(i)
        if i in dirty:
            cache.update(i, jnp.asarray(page) * 2.0)
    cache.flush()
    # only update()d layers were written back; clean evictions are free
    assert cache.stats["writebacks"] == len(dirty)
    for i in range(L):
        want = pages[i] * 2.0 if i in dirty else pages[i]
        np.testing.assert_allclose(cache._host[i], want)
    cache.close()


def test_offloaded_kv_cache_flush_drains_pending():
    from repro.runtime.offload import OffloadedKVCache

    L = 4
    cache = OffloadedKVCache(num_layers=L, window=2)
    for i in range(L):
        cache.host_put(i, np.full((2, 2), i, np.float32))
    cache.fetch(0)                      # issues the prefetch of layer 1
    assert 1 in cache._pending or 1 in cache._resident
    cache.flush()                       # must land the in-flight transfer
    assert cache._pending == {}
    assert cache._resident == {}
    assert cache.stats["writebacks"] == 0   # nothing was update()d
    np.testing.assert_array_equal(cache._host[1], np.full((2, 2), 1))
    cache.close()


def test_offloaded_kv_cache_missing_layer_raises_not_hangs():
    import pytest

    from repro.runtime.offload import OffloadedKVCache

    cache = OffloadedKVCache(num_layers=3, window=2)
    cache.host_put(0, np.zeros((2, 2), np.float32))
    # prefetched transfer of a never-host_put layer: the worker error must
    # surface at fetch() instead of deadlocking on the queue
    cache.prefetch(1)
    with pytest.raises(RuntimeError, match="layer 1"):
        cache.fetch(1)
    # demand path too
    with pytest.raises(RuntimeError, match="host_put"):
        cache.fetch(2)
    cache.close()

def test_offloaded_kv_cache_retries_flaky_uploads():
    import pytest

    from repro.runtime.offload import OffloadedKVCache

    class Flaky(OffloadedKVCache):
        """Upload worker whose first `fail_first` _upload calls die with a
        transient error — the seam the retry loop is specified against."""

        def __init__(self, *a, fail_first=0, **kw):
            super().__init__(*a, **kw)
            self._fail_left = fail_first

        def _upload(self, layer, host_page):
            if self._fail_left > 0:
                self._fail_left -= 1
                raise OSError("transient NIC hiccup")
            return super()._upload(layer, host_page)

    page = np.arange(4, dtype=np.float32).reshape(2, 2)

    # default max_retries=0: the first failure propagates at fetch()
    cache = Flaky(num_layers=1, window=1, fail_first=1)
    cache.host_put(0, page)
    cache.prefetch(0)
    with pytest.raises(RuntimeError, match="layer 0"):
        cache.fetch(0)
    cache.close()

    # bounded retry with backoff recovers from transient failures
    cache = Flaky(num_layers=1, window=1, fail_first=2,
                  max_retries=3, retry_backoff_s=0.0)
    cache.host_put(0, page)
    cache.prefetch(0)
    np.testing.assert_array_equal(np.asarray(cache.fetch(0)), page)
    assert cache.stats["prefetch_retries"] == 2
    cache.close()

    # exhaustion: persistent failure still surfaces, naming the budget
    cache = Flaky(num_layers=1, window=1, fail_first=99,
                  max_retries=2, retry_backoff_s=0.0)
    cache.host_put(0, page)
    cache.prefetch(0)
    with pytest.raises(RuntimeError, match="after 2 retries"):
        cache.fetch(0)
    cache.close()


def test_offloaded_kv_cache_rejects_negative_retry_knobs():
    import pytest

    from repro.runtime.offload import OffloadedKVCache

    with pytest.raises(ValueError, match="max_retries"):
        OffloadedKVCache(num_layers=1, max_retries=-1)
    with pytest.raises(ValueError, match="retry_backoff_s"):
        OffloadedKVCache(num_layers=1, retry_backoff_s=-0.5)
