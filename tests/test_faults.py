"""Fault-injected far memory: error status, retry/backoff, failover.

The contract under test (TESTING.md "Fault injection"):

* fault draws come from a dedicated per-region stream spawned off the
  region's RNG lineage, so fault schedules are deterministic and
  batch/scalar bitstream-identical — faulty runs are trace-identical
  between the scalar and batched ENGINES under a fixed scheduler, and
  bit-identical between the per-command and epoch-fused schedulers on the
  same engine, for every registered port (incl. `paged_kv_serve`);
* zero-fault configs are bit-identical whether or not a RetryPolicy is
  attached — statuses travel out of band, traces and summaries carry no
  fault keys;
* failed requests move no data; after the scheduler's retries and one
  failover attempt are exhausted, the awaiting coroutine receives the
  final status (int for a single-token await, per-lane int8 array for a
  vector await);
* `RunStats` reports faults_injected / retries / timeouts / failovers /
  availability; `reset_stats()` clears prepare-phase fault state so it
  cannot leak into a measured execute() split;
* fault-config validation names the offending region (negative
  probabilities, overlapping outage windows, failover cycles).
"""
import numpy as np
import pytest

from repro.amu import (REGISTRY, STATUS_ERROR, STATUS_OK, STATUS_TIMED_OUT,
                       AmuConfig, AmuSession, FaultModel, LinkFlap,
                       RetryPolicy, far_region)
from repro.configs.base import EngineConfig
from repro.core.coroutines import (SCHEDULER_KINDS, Aload, AloadVec, SpmRead)
from repro.core.engine import make_engine
from repro.core.farmem import FarMemoryConfig, FarMemoryModel
from repro.core.serving import serve_regions

RETRY = RetryPolicy(max_retries=3, backoff=200.0)


def _fault_regions(mem_bytes, error_prob=0.04, drop_prob=0.02,
                   failover=True, flaps=()):
    """A faulted 'fabric' tier covering the whole port address space, plus
    a clean slower 'backup' tier for failover."""
    size = max((int(mem_bytes) + 63) // 64 * 64, 64)
    fm = FaultModel(error_prob=error_prob, drop_prob=drop_prob,
                    flaps=tuple(flaps))
    return [far_region("fabric", 0, size, 1.0, faults=fm,
                       failover="backup" if failover else None),
            far_region("backup", size, size, 3.0)]


def _mem_size(wl, vector=False):
    return REGISTRY.build(wl, 0, vector=vector).mem.size


def _capture(wl, engine, sched, far, retry=RETRY, vector=False, **build_kw):
    cfg = AmuConfig(engine=engine, scheduler=sched, far=far, retry=retry,
                    vector=vector)
    with AmuSession(cfg) as s:
        st = s.run(wl, record_trace=True, **build_kw)
        return st, list(s.engine.trace), s.engine.mem.copy()


def _stats_no_host_counters(st):
    d = st.to_dict()
    for k in ("engine_entries", "rows_per_entry"):
        d.pop(k)
    return d


# =========================================================================
# Differential pinning: faulty runs across engines and scheduler fusion
# =========================================================================
@pytest.mark.parametrize("wl", REGISTRY.names())
def test_faulty_runs_trace_identical_across_engines_and_fusion(wl):
    far = _fault_regions(_mem_size(wl))
    a = _capture(wl, "scalar", "batched", far)
    b = _capture(wl, "batched", "batched", far)
    c = _capture(wl, "batched", "fused", far)
    # retry + failover recover every request, so the run stays correct
    assert a[0].verified is True
    assert a[1] == b[1] == c[1]                  # issue/fin trace
    assert np.array_equal(a[2], b[2]) and np.array_equal(b[2], c[2])
    # engines: everything identical; mlp alone compared with tolerance
    # (the ledger's accumulation order differs between flat and batched
    # record paths by ~1e-14 — a pre-existing zero-fault property)
    da, db = _stats_no_host_counters(a[0]), _stats_no_host_counters(b[0])
    ma, mb = da.pop("mlp"), db.pop("mlp")
    assert da == db
    assert np.isclose(ma, mb, rtol=1e-9, atol=0.0)
    # fused vs per-command on the same engine: bit-identical, mlp included
    assert _stats_no_host_counters(b[0]) == _stats_no_host_counters(c[0])


@pytest.mark.parametrize("wl", ["GUPS", "STREAM", "LL", "paged_kv_serve"])
def test_faulty_vector_ports_differential(wl):
    far = _fault_regions(_mem_size(wl, vector=True))
    a = _capture(wl, "scalar", "batched", far, vector=True)
    b = _capture(wl, "batched", "batched", far, vector=True)
    c = _capture(wl, "batched", "fused", far, vector=True)
    assert a[0].verified is True
    assert a[1] == b[1] == c[1]
    assert np.array_equal(a[2], b[2]) and np.array_equal(b[2], c[2])
    assert _stats_no_host_counters(b[0]) == _stats_no_host_counters(c[0])


def test_faulty_scalar_scheduler_survives_on_both_engines():
    """The scalar scheduler (the semantic oracle loop) also runs the retry
    plane; both engines under it recover to full availability."""
    far = _fault_regions(_mem_size("GUPS"))
    for engine in ("scalar", "batched"):
        st, _, _ = _capture("GUPS", engine, "scalar", far)
        assert st.verified is True
        assert st.availability == 1.0
        assert st.faults_injected > 0 and st.retries > 0


# =========================================================================
# Zero-fault bit-identity: the fault plane is invisible until armed
# =========================================================================
@pytest.mark.parametrize("engine,sched", [("scalar", "scalar"),
                                          ("scalar", "batched"),
                                          ("batched", "batched"),
                                          ("batched", "fused")])
def test_zero_fault_retry_policy_is_invisible(engine, sched):
    out = {}
    for tag, retry in (("plain", None), ("retry", RETRY)):
        cfg = AmuConfig(engine=engine, scheduler=sched, retry=retry,
                        far=[far_region("all", 0, 1 << 22, 1.0)])
        with AmuSession(cfg) as s:
            st = s.run("GUPS", record_trace=True)
            assert st.verified is True
            out[tag] = (st.to_dict(), list(s.engine.trace),
                        dict(s.scheduler.summary()))
    assert out["plain"] == out["retry"]
    # no fault keys leak into a zero-fault summary
    for key in ("faults_injected", "retries", "timeouts", "failovers",
                "availability", "failed"):
        assert key not in out["plain"][2]
    # RunStats carries the idle defaults
    assert out["plain"][0]["faults_injected"] == 0
    assert out["plain"][0]["availability"] == 1.0


def test_zero_fault_flat_model_with_retry_policy():
    a = AmuConfig(engine="batched", latency_us=1.0)
    b = a.derive(retry=RETRY)
    runs = []
    for cfg in (a, b):
        with AmuSession(cfg) as s:
            st = s.run("GUPS", record_trace=True)
            runs.append((st.to_dict(), list(s.engine.trace)))
    assert runs[0] == runs[1]


# =========================================================================
# Status delivery + data movement (scheduler-level, deterministic)
# =========================================================================
def _drive_tasks(tasks, far_cfg, retry=None, sched="batched",
                 timeout_cycles=0.0, mem_fill=0):
    ecfg = EngineConfig(queue_length=64, granularity=8, spm_bytes=4096,
                        batch_ids=16)
    far = FarMemoryModel(far_cfg, timeout_cycles=timeout_cycles)
    mem = np.full(1 << 16, mem_fill, np.uint8)
    eng = make_engine("batched", ecfg, far, mem)
    s = SCHEDULER_KINDS[sched](eng, retry=retry)
    summary = s.run(tasks)
    eng.drain()
    eng.check_invariants()
    return summary, eng


def _always_error_cfg():
    return FarMemoryConfig(regions=(
        far_region("bad", 0, 1 << 16, 1.0,
                   faults=FaultModel(error_prob=1.0)),))


@pytest.mark.parametrize("sched", sorted(SCHEDULER_KINDS))
def test_final_failure_status_reaches_the_coroutine(sched):
    got = {}

    def task():
        got["scalar"] = yield Aload(0, 64, 8)
        got["vector"] = yield AloadVec(np.array([8, 16]),
                                       np.array([128, 256]), 8, wait=True)

    summary, _ = _drive_tasks([task()], _always_error_cfg(),
                              retry=RetryPolicy(max_retries=1, backoff=50.0),
                              sched=sched)
    assert got["scalar"] == STATUS_ERROR
    np.testing.assert_array_equal(
        np.asarray(got["vector"]), np.full(2, STATUS_ERROR, np.int8))
    assert summary["retries"] == 3               # one per original request
    assert summary["failed"] == 3
    assert summary["availability"] == 0.0


def test_status_delivered_immediately_without_retry_policy():
    got = {}

    def task():
        got["st"] = yield Aload(0, 64, 8)

    summary, _ = _drive_tasks([task()], _always_error_cfg())
    assert got["st"] == STATUS_ERROR
    assert summary["retries"] == 0 and summary["failed"] == 1


def test_dropped_requests_surface_timed_out():
    cfg = FarMemoryConfig(regions=(
        far_region("droppy", 0, 1 << 16, 1.0,
                   faults=FaultModel(drop_prob=1.0)),))
    got = {}

    def task():
        got["st"] = yield Aload(0, 64, 8)

    summary, _ = _drive_tasks([task()], cfg)
    assert got["st"] == STATUS_TIMED_OUT
    assert summary["timeouts"] == 1


def test_client_side_timeout_classifies_slow_requests():
    """RetryPolicy.timeout_cycles arms a client-side timer: a region with
    no FaultModel at all still times requests out when their modeled
    completion exceeds the budget."""
    cfg = FarMemoryConfig.from_latency_us(5.0)   # 15000-cycle base latency
    got = {}

    def task():
        got["st"] = yield Aload(0, 64, 8)

    summary, _ = _drive_tasks([task()], cfg, timeout_cycles=1000.0)
    assert got["st"] == STATUS_TIMED_OUT
    assert summary["timeouts"] == 1


def test_failed_requests_move_no_data():
    seen = {}

    def task():
        st = yield Aload(0, 64, 8)
        assert st == STATUS_ERROR
        data = yield SpmRead(0, 8)
        seen["bytes"] = bytes(data)

    _drive_tasks([task()], _always_error_cfg(), mem_fill=0xAB)
    # far memory holds 0xAB everywhere, but the failed load must not have
    # copied it into the (zero-initialized) SPM
    assert seen["bytes"] == b"\x00" * 8


def test_successful_await_still_resumes_with_ok_status():
    got = {}

    def task():
        got["st"] = yield Aload(0, 64, 8)

    cfg = FarMemoryConfig(regions=(
        far_region("fine", 0, 1 << 16, 1.0,
                   faults=FaultModel(error_prob=0.0)),))
    _drive_tasks([task()], cfg)
    assert got["st"] == STATUS_OK                # fault mode: explicit OK


# =========================================================================
# Recovery: retries, failover, outage survival
# =========================================================================
def test_failover_absorbs_retry_exhaustion():
    """Fabric errors every request: each exhausts max_retries, then one
    failover to the clean backup tier succeeds — full availability, and
    the request accounting closes exactly."""
    far = _fault_regions(_mem_size("GUPS"), error_prob=1.0, drop_prob=0.0)
    st, _, _ = _capture("GUPS", "batched", "fused", far)
    assert st.verified is True
    assert st.availability == 1.0
    assert st.failovers > 0
    # every original request burned max_retries retries then failed over
    assert st.retries == st.failovers * RETRY.max_retries
    assert st.requests == st.failovers + st.retries + st.failovers


@pytest.mark.parametrize("vector", [False, True], ids=["scalar", "vector"])
def test_serving_survives_mid_run_outage(vector):
    """paged_kv_serve through a 60k-cycle link outage on the cross-switch
    tier with retry + failover to CXL: the run completes, stays correct,
    and reports full availability."""
    fm = FaultModel(error_prob=0.01,
                    flaps=(LinkFlap(20_000.0, 60_000.0, mode="error"),))
    regs = serve_regions(faults=fm, failover="cxl")
    cfg = AmuConfig(engine="batched", far=regs, retry=RETRY, vector=vector)
    with AmuSession(cfg) as s:
        st = s.run("paged_kv_serve")
    assert st.verified is True
    assert st.faults_injected > 0 and st.retries > 0
    assert st.availability == 1.0
    assert st.req_p999_us > 0


def test_serving_outage_differentially_pinned():
    """The outage run itself is pinned: scalar vs batched engine under the
    per-command scheduler, and per-command vs fused on the batched engine."""
    fm = FaultModel(error_prob=0.01,
                    flaps=(LinkFlap(20_000.0, 60_000.0, mode="error"),))
    regs = serve_regions(faults=fm, failover="cxl")
    caps = {}
    for engine, sched in (("scalar", "batched"), ("batched", "batched"),
                          ("batched", "fused")):
        cfg = AmuConfig(engine=engine, scheduler=sched, far=regs,
                        retry=RETRY)
        with AmuSession(cfg) as s:
            st = s.run("paged_kv_serve", record_trace=True)
            caps[(engine, sched)] = (st, list(s.engine.trace))
    t1, t2, t3 = (caps[k][1] for k in caps)
    assert t1 == t2 == t3
    s2, s3 = caps[("batched", "batched")][0], caps[("batched", "fused")][0]
    assert _stats_no_host_counters(s2) == _stats_no_host_counters(s3)


def test_serving_degrades_without_retry_policy():
    """No RetryPolicy: statuses reach the port, whose sync_fallback keeps
    the fold correct (verified) while availability honestly reports the
    AMI-plane failures."""
    regs = serve_regions(faults=FaultModel(error_prob=0.05), failover=None)
    with AmuSession(AmuConfig(engine="batched", far=regs)) as s:
        st = s.run("paged_kv_serve")
    assert st.verified is True
    assert st.faults_injected > 0
    assert st.retries == 0 and st.failovers == 0
    assert st.availability < 1.0


# =========================================================================
# reset_stats: prepare-phase faults cannot leak into execute()
# =========================================================================
def test_reset_stats_clears_prepare_phase_fault_state():
    far = _fault_regions(_mem_size("GUPS"), error_prob=1.0, drop_prob=0.0)
    cfg = AmuConfig(engine="batched", far=far, retry=RETRY)
    with AmuSession(cfg) as s:
        s.prepare("GUPS")
        # warmup traffic through the always-erroring fabric tier
        for i in range(16):
            s.far.issue(float(i), 64, i * 64)
        assert s.far.faults_injected == 16
        assert s.far.last_status != STATUS_OK
        s.far.reset_stats()
        assert s.far.faults_injected == 0
        assert s.far.errors == 0 and s.far.timeouts == 0
        assert s.far.last_status == STATUS_OK
        assert s.far.last_statuses is None
        measured = s.execute()
    # with error_prob=1.0 every measured-phase fault produced exactly one
    # retry or failover re-issue; a leaked warmup fault would break this
    assert measured.faults_injected == measured.retries + measured.failovers
    assert measured.availability == 1.0
    assert measured.verified is True


def test_scheduler_reset_stats_clears_retry_plane():
    got = {}

    def task():
        got["st"] = yield Aload(0, 64, 8)

    ecfg = EngineConfig(queue_length=64, granularity=8, spm_bytes=4096,
                        batch_ids=16)
    far = FarMemoryModel(_always_error_cfg())
    eng = make_engine("batched", ecfg, far, np.zeros(1 << 16, np.uint8))
    sched = SCHEDULER_KINDS["batched"](
        eng, retry=RetryPolicy(max_retries=2, backoff=50.0))
    sched.run([task()])
    assert sched.n_retries == 2 and sched.n_failed == 1
    far.reset_stats()
    sched.reset_stats()
    assert sched.n_retries == sched.n_failovers == sched.n_failed == 0
    assert not sched._retry_heap and not sched._tok_req
    assert not sched._tok_fstat and not sched._group_toks
    assert sched.summary()["faults_injected"] == 0


# =========================================================================
# Cross-matrix: fault plane × epoch fusion × per-region stats
# =========================================================================
def _multi_fault_regions(mem_bytes):
    """Both tiers faulted (distinct rates) so each accumulates its own
    error/timeout counters, plus a clean failover target."""
    size = max((int(mem_bytes) + 63) // 64 * 64, 64)
    half = size // 2 // 64 * 64
    return [far_region("fabric", 0, half, 1.0,
                       faults=FaultModel(error_prob=0.06, drop_prob=0.03),
                       failover="backup"),
            far_region("xswitch", half, size - half, 3.0,
                       faults=FaultModel(error_prob=0.10),
                       failover="backup"),
            far_region("backup", size, size, 5.0)]


@pytest.mark.parametrize("sched", ["auto", "batched"])
def test_region_fault_counters_populated_under_both_schedulers(sched):
    far = _multi_fault_regions(_mem_size("GUPS"))
    st, _, _ = _capture("GUPS", "batched", sched, far)
    assert st.verified is True
    assert set(st.regions) == {"fabric", "xswitch", "backup"}
    for name in ("fabric", "xswitch"):
        r = st.regions[name]
        assert "errors" in r and "timeouts" in r
    # distinct fault models actually fired on both faulted tiers
    assert st.regions["fabric"]["errors"] + st.regions["fabric"]["timeouts"] > 0
    assert st.regions["xswitch"]["errors"] > 0
    assert st.regions["backup"]["errors"] == 0
    # per-region counters are the device-side split of the run total
    assert sum(r.get("errors", 0) + r.get("timeouts", 0)
               for r in st.regions.values()) == st.faults_injected


def test_region_fault_counters_identical_fused_vs_percommand():
    """The epoch-fused scheduler must produce the exact per-region
    error/timeout split of the per-command loop on a multi-region faulty
    run — RunStats.regions is part of the fusion identity contract."""
    far = _multi_fault_regions(_mem_size("GUPS"))
    a = _capture("GUPS", "batched", "batched", far)
    b = _capture("GUPS", "batched", "auto", far)    # auto -> fused
    assert a[1] == b[1]
    assert a[0].regions == b[0].regions
    assert _stats_no_host_counters(a[0]) == _stats_no_host_counters(b[0])


def test_reset_stats_zeroes_region_counters_under_both_schedulers():
    """reset_stats() must clear the per-region error/timeout counters (and
    the link-occupancy ledger) identically under the fused and per-command
    loops, and the post-reset measured split must stay bit-identical
    between the two scheduler kinds (warmup traffic legitimately advances
    link free-times and RNG streams, so the comparison is fused-vs-
    per-command, not warmed-vs-fresh)."""
    out = {}
    far = _multi_fault_regions(_mem_size("GUPS"))
    for sched in ("auto", "batched"):       # auto -> fused on this engine
        cfg = AmuConfig(engine="batched", scheduler=sched, far=far,
                        retry=RETRY)
        with AmuSession(cfg) as s:
            s.prepare("GUPS")
            # warmup traffic across both faulted tiers
            for i in range(32):
                s.far.issue(float(i), 64, i * 64)
            assert s.far.faults_injected > 0
            assert s.far.link_busy                # occupancy accumulated
            s.far.reset_stats()
            s.scheduler.reset_stats()
            assert s.far.link_busy == {}
            for r in s.far.region_stats(1.0).values():
                assert r["requests"] == 0 and r["bytes"] == 0
                assert r.get("errors", 0) == 0 and r.get("timeouts", 0) == 0
            st = s.execute()
            assert st.verified is True
            out[sched] = (st, list(s.far.link_busy))
    st_a, links_a = out["auto"]
    st_b, links_b = out["batched"]
    assert st_a.regions == st_b.regions
    assert links_a == links_b
    assert _stats_no_host_counters(st_a) == _stats_no_host_counters(st_b)


# =========================================================================
# Validation: errors name the offending region
# =========================================================================
def test_negative_probabilities_rejected():
    with pytest.raises(ValueError, match="fabric.*probabilities"):
        AmuConfig(far=[far_region("fabric", 0, 4096, 1.0,
                                  faults=FaultModel(error_prob=-0.1))])


def test_overlapping_outage_windows_rejected():
    flaps = (LinkFlap(0.0, 100.0), LinkFlap(50.0, 100.0))
    with pytest.raises(ValueError, match="fabric.*overlapping"):
        AmuConfig(far=[far_region("fabric", 0, 4096, 1.0,
                                  faults=FaultModel(flaps=flaps))])


def test_failover_cycles_rejected():
    a = far_region("a", 0, 4096, 1.0, failover="b")
    b = far_region("b", 4096, 4096, 1.0, failover="a")
    with pytest.raises(ValueError, match="failover cycle"):
        AmuConfig(far=[a, b])
    with pytest.raises(ValueError, match="itself"):
        AmuConfig(far=[far_region("a", 0, 4096, 1.0, failover="a")])
    with pytest.raises(ValueError, match="unknown"):
        AmuConfig(far=[far_region("a", 0, 4096, 1.0, failover="ghost")])


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="timeout_cycles"):
        RetryPolicy(timeout_cycles=-1.0)
    with pytest.raises(ValueError, match="backoff"):
        RetryPolicy(backoff=-1.0)
    with pytest.raises(TypeError, match="RetryPolicy"):
        AmuConfig(retry=3)
