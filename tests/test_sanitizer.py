"""Runtime AMI sanitizer: neutrality (bit-identical off/on) + detection.

Two halves mirror the sanitizer's contract:

* ``sanitize=True`` is pure observation — traces, stats, and the far-memory
  RNG bitstream must be bit-identical with it off, on every engine x
  scheduler combination and on the multi-core rack.
* each runtime violation class (leaked rid, racing spm_read, reversed
  Acquire order, duplicate acquire, non-ascending AcquireVec) raises an
  :class:`AmiProtocolError` diagnostic naming the port, on the batched
  AND epoch-fused planes (the scalar oracle catches the SPM race with its
  own assertion in the same shared message format).
"""
import numpy as np
import pytest

from repro.amu import AmuConfig, AmuSession, ctx
from repro.amu.session import RackSession
from repro.analysis import AmiProtocolError
from repro.core.workloads import WorkloadInstance, _cfg

from proplib import given, settings, st

COMBOS = [("scalar", "scalar"), ("batched", "batched"), ("batched", "fused")]


def _run(engine, sched, name, sanitize, **kw):
    cfg = AmuConfig(engine=engine, scheduler=sched, sanitize=sanitize, **kw)
    s = AmuSession(cfg)
    stats = s.run(name, record_trace=True)
    trace = list(s.engine.trace)
    rng = s.far._rng.bit_generator.state
    s.close()
    return trace, stats.to_dict(), rng


# ======================================================================
# neutrality: sanitize=True must not perturb anything observable
# ======================================================================

@pytest.mark.parametrize("engine,sched", COMBOS)
@pytest.mark.parametrize("name", ["GUPS", "HJ", "SL"])
def test_sanitize_neutral(engine, sched, name):
    t0, s0, r0 = _run(engine, sched, name, sanitize=False)
    t1, s1, r1 = _run(engine, sched, name, sanitize=True)
    assert t0 == t1, "sanitize=True changed the issue/fin trace"
    assert s0 == s1, "sanitize=True changed the run stats"
    assert r0 == r1, "sanitize=True consumed far-memory RNG draws"


@pytest.mark.parametrize("name", ["GUPS", "SL"])
def test_sanitize_neutral_vector(name):
    t0, s0, r0 = _run("batched", "fused", name, sanitize=False, vector=True)
    t1, s1, r1 = _run("batched", "fused", name, sanitize=True, vector=True)
    assert (t0, s0, r0) == (t1, s1, r1)


def test_sanitize_neutral_rack():
    out = {}
    for san in (False, True):
        cfg = AmuConfig(engine="batched", scheduler="fused", cores=4,
                        sanitize=san)
        rs = RackSession(cfg)
        stats = rs.run("GUPS")
        out[san] = ([c.to_dict() for c in stats.cores],
                    rs.far._rng.bit_generator.state)
        rs.close()
    assert out[False] == out[True]


# ======================================================================
# detection fixtures
# ======================================================================

def _inst(tasks, disamb=False):
    mem = np.zeros(4096, np.uint8)
    return WorkloadInstance("FIXTURE", mem, tasks, 1, _cfg(8),
                            lambda m: True, disambiguation=disamb)


def _leaked():
    yield ctx.aload(0, 64, 8, wait=False)
    yield ctx.cost(1)


def _racing():
    rid = yield ctx.aload(0, 64, 8, wait=False)
    _ = yield ctx.spm_read(0, 8)
    yield ctx.await_rid(rid)


def _locker(a, b):
    yield ctx.acquire(a)
    yield ctx.acquire(b)
    yield ctx.release(b)
    yield ctx.release(a)


def _dup_acquire():
    yield ctx.acquire(64)
    yield ctx.acquire(64)
    yield ctx.release(64)
    yield ctx.release(64)


def _vec_bad():
    yield ctx.acquire_vec([128, 64])
    yield ctx.release_vec([128, 64])


def _catch(engine, sched, inst, match):
    cfg = AmuConfig(engine=engine, scheduler=sched, sanitize=True)
    with pytest.raises(AssertionError, match=match):
        AmuSession(cfg).run(inst)


@pytest.mark.parametrize("engine,sched", COMBOS)
def test_detect_leaked_rid(engine, sched):
    _catch(engine, sched, _inst([_leaked()]),
           match="leaked 1 request token")


@pytest.mark.parametrize("engine,sched", COMBOS)
def test_detect_racing_spm_read(engine, sched):
    # scalar: the oracle's own overlap assert fires first — same shared
    # format_race message, so one match covers all three planes
    _catch(engine, sched, _inst([_racing()]),
           match=r"races in-flight aload rid=1 \(port 'FIXTURE'\)")


@pytest.mark.parametrize("engine,sched", COMBOS)
def test_detect_reversed_lock_order(engine, sched):
    _catch(engine, sched,
           _inst([_locker(64, 128), _locker(128, 64)], disamb=True),
           match="lock-order cycle")


@pytest.mark.parametrize("engine,sched", COMBOS)
def test_detect_duplicate_acquire(engine, sched):
    _catch(engine, sched, _inst([_dup_acquire()], disamb=True),
           match="self-deadlock")


@pytest.mark.parametrize("engine,sched", COMBOS)
def test_detect_nonascending_acquire_vec(engine, sched):
    _catch(engine, sched, _inst([_vec_bad()], disamb=True),
           match="strictly ascending and distinct")


@pytest.mark.parametrize("engine,sched", COMBOS)
def test_detect_release_without_acquire(engine, sched):
    def t():
        yield ctx.release(64)
    _catch(engine, sched, _inst([t()], disamb=True),
           match="does not hold")


@pytest.mark.parametrize("engine,sched", COMBOS)
def test_detect_exit_holding_lock(engine, sched):
    def t():
        yield ctx.acquire(64)
        yield ctx.cost(1)
    _catch(engine, sched, _inst([t()], disamb=True),
           match="Acquire without Release")


def test_violation_error_is_assertion_subclass():
    assert issubclass(AmiProtocolError, AssertionError)


def test_env_var_default(monkeypatch):
    monkeypatch.setenv("AMU_SANITIZE", "1")
    assert AmuConfig().sanitize is True
    monkeypatch.setenv("AMU_SANITIZE", "0")
    assert AmuConfig().sanitize is False
    monkeypatch.delenv("AMU_SANITIZE")
    assert AmuConfig().sanitize is False


# ======================================================================
# property: clean random GUPS-like ports never trip the sanitizer, and
# leaking any single token always trips it
# ======================================================================

@settings(max_examples=12, deadline=None)
@given(n=st.integers(min_value=1, max_value=16),
       leak_at=st.integers(min_value=-1, max_value=15),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_leak_detection(n, leak_at, seed):
    """A port issuing n wait=False loads and awaiting all but (maybe) one:
    sanitize=True passes iff nothing leaked."""
    leak = 0 <= leak_at < n

    def port():
        rids = []
        for i in range(n):
            r = yield ctx.aload(i * 8, 64 + i * 8, 8, wait=False)
            if i != leak_at:
                rids.append(r)
        yield ctx.await_rids(rids)

    cfg = AmuConfig(engine="batched", scheduler="fused", sanitize=True,
                    seed=seed)
    sess = AmuSession(cfg)
    if leak:
        with pytest.raises(AmiProtocolError, match="leaked 1 request"):
            sess.run(_inst([port()]))
    else:
        sess.run(_inst([port()]))
    sess.close()
