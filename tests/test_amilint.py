"""Static AMI protocol lint (amilint): real ports stay clean, seeded
violations in fixture sources trip the right rule, suppression works,
and the CLI round-trips text + JSON."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.amu import REGISTRY
from repro.analysis import lint_registry, lint_source
from repro.analysis.amilint import FACADE_METHODS, lint_file, render
from repro.amu.commands import CommandFacade

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lint(src):
    return lint_source(textwrap.dedent(src), "<fixture>")


def _rules(src):
    return [f.rule for f in _lint(src)]


# ======================================================================
# real in-repo ports are clean
# ======================================================================

def test_registry_source_files_found():
    files = REGISTRY.source_files()
    assert any(p.endswith("workloads.py") for p in files)
    assert any(p.endswith("serving.py") for p in files)


def test_registry_ports_clean():
    findings = lint_registry(REGISTRY)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_example_port_clean():
    path = os.path.join(REPO, "examples", "amu_workload.py")
    findings = lint_file(path)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_facade_methods_in_sync():
    """amilint's facade list must track the real CommandFacade surface."""
    real = {n for n in dir(CommandFacade)
            if not n.startswith("_")
            and isinstance(CommandFacade.__dict__.get(n), staticmethod)}
    assert real == FACADE_METHODS


# ======================================================================
# AMI001 — leaked request IDs
# ======================================================================

def test_leak_discarded_token():
    assert _rules("""
        def task(ctx):
            yield ctx.aload(0, 64, 8, wait=False)
            yield ctx.cost(1)
    """) == ["AMI001"]


def test_leak_never_awaited():
    assert _rules("""
        def task(ctx):
            rid = yield ctx.aload(0, 64, 8, wait=False)
            yield ctx.cost(1)
    """) == ["AMI001"]


def test_leak_conditional_await():
    assert _rules("""
        def task(ctx, flag):
            rid = yield ctx.aload(0, 64, 8, wait=False)
            if flag:
                yield ctx.await_rid(rid)
    """) == ["AMI001"]


def test_no_leak_direct_await():
    assert _rules("""
        def task(ctx):
            rid = yield ctx.aload(0, 64, 8, wait=False)
            yield ctx.await_rid(rid)
    """) == []


def test_no_leak_via_list():
    """Token flowing through a container into await_rids is tracked."""
    assert _rules("""
        def task(ctx):
            rids = []
            for i in range(4):
                r = yield ctx.aload(i * 8, 64 + i * 8, 8, wait=False)
                rids.append(r)
            yield ctx.await_rids(rids)
    """) == []


def test_no_leak_raw_vec_default_nowait():
    """Raw AloadVec defaults wait=False (unlike the facade) — an
    un-awaited raw vec issue leaks."""
    assert _rules("""
        def task(ctx):
            yield AloadVec(slots, addrs, 8)
            yield ctx.cost(1)
    """) == ["AMI001"]


# ======================================================================
# AMI002 — SPM races against in-flight loads
# ======================================================================

def test_race_read_overlap():
    assert _rules("""
        def task(ctx):
            rid = yield ctx.aload(0, 64, 8, wait=False)
            v = yield ctx.spm_read(0, 8)
            yield ctx.await_rid(rid)
    """) == ["AMI002"]


def test_race_cleared_by_await():
    assert _rules("""
        def task(ctx):
            rid = yield ctx.aload(0, 64, 8, wait=False)
            yield ctx.await_rid(rid)
            v = yield ctx.spm_read(0, 8)
    """) == []


def test_race_disjoint_windows():
    assert _rules("""
        def task(ctx):
            rid = yield ctx.aload(0, 64, 8, wait=False)
            v = yield ctx.spm_read(16, 8)
            yield ctx.await_rid(rid)
    """) == []


def test_race_symbolic_base():
    """slot+0 load vs slot+4 write: same base, overlapping constants."""
    assert _rules("""
        def task(ctx, slot):
            rid = yield ctx.aload(slot, 64, 8, wait=False)
            yield ctx.spm_write(slot + 4, b"xx")
            yield ctx.await_rid(rid)
    """) == ["AMI002"]


def test_race_different_bases_quiet():
    """Different symbolic bases are incomparable — no finding."""
    assert _rules("""
        def task(ctx, a, b):
            rid = yield ctx.aload(a, 64, 8, wait=False)
            v = yield ctx.spm_read(b, 8)
            yield ctx.await_rid(rid)
    """) == []


def test_race_wait_true_never_opens_window():
    assert _rules("""
        def task(ctx):
            yield ctx.aload(0, 64, 8)
            v = yield ctx.spm_read(0, 8)
    """) == []


# ======================================================================
# AMI003 / AMI004 — lock matching and ordering
# ======================================================================

def test_acquire_without_release():
    assert _rules("""
        def task(ctx):
            yield ctx.acquire(64)
            yield ctx.cost(1)
    """) == ["AMI003"]


def test_release_without_acquire():
    assert _rules("""
        def task(ctx):
            yield ctx.release(64)
    """) == ["AMI003"]


def test_lock_order_reversed():
    assert _rules("""
        def task(ctx):
            yield ctx.acquire(128)
            yield ctx.acquire(64)
            yield ctx.release(64)
            yield ctx.release(128)
    """) == ["AMI004"]


def test_lock_order_duplicate():
    assert _rules("""
        def task(ctx):
            yield ctx.acquire(64)
            yield ctx.acquire(64)
            yield ctx.release(64)
            yield ctx.release(64)
    """) == ["AMI004"]


def test_lock_order_ascending_ok():
    assert _rules("""
        def task(ctx):
            yield ctx.acquire(64)
            yield ctx.acquire(128)
            yield ctx.release(64)
            yield ctx.release(128)
    """) == []


def test_acquire_vec_nonascending():
    assert _rules("""
        def task(ctx):
            yield ctx.acquire_vec([128, 64])
            yield ctx.release_vec([128, 64])
    """) == ["AMI004"]


def test_acquire_vec_unpaired():
    assert _rules("""
        def task(ctx):
            yield ctx.acquire_vec([64, 128])
            yield ctx.cost(1)
    """) == ["AMI003"]


# ======================================================================
# AMI005 / AMI006 — non-command yields, engine bypass
# ======================================================================

@pytest.mark.parametrize("body,why", [
    ("yield 42", "constant"),
    ("yield", "bare"),
    ("yield ctx.frobnicate(1)", "unknown facade method"),
])
def test_non_command_yield(body, why):
    assert _rules(f"""
        def task(ctx):
            {body}
            yield ctx.cost(1)
    """) == ["AMI005"], why


def test_engine_bypass():
    assert _rules("""
        def task(ctx, eng):
            eng.spm_write(0, b"xx")
            yield ctx.cost(1)
    """) == ["AMI006"]


def test_non_port_function_ignored():
    """Functions that never yield commands are out of scope entirely."""
    assert _rules("""
        def helper(eng):
            return eng.spm_read(0, 8)
    """) == []


# ======================================================================
# suppression + rendering + CLI
# ======================================================================

def test_suppression_targeted():
    assert _rules("""
        def task(ctx):
            yield ctx.aload(0, 64, 8, wait=False)  # amilint: ignore[AMI001]
            yield ctx.cost(1)
    """) == []


def test_suppression_wrong_rule_keeps_finding():
    assert _rules("""
        def task(ctx):
            yield ctx.aload(0, 64, 8, wait=False)  # amilint: ignore[AMI002]
            yield ctx.cost(1)
    """) == ["AMI001"]


def test_render_json():
    findings = _lint("""
        def task(ctx):
            yield ctx.acquire(64)
            yield ctx.cost(1)
    """)
    blob = json.loads(render(findings, as_json=True))
    assert blob["count"] == 1
    assert blob["findings"][0]["rule"] == "AMI003"
    assert blob["findings"][0]["func"] == "task"


def test_cli_clean_and_dirty(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    tool = os.path.join(REPO, "tools", "amilint.py")
    ex = os.path.join(REPO, "examples", "amu_workload.py")
    r = subprocess.run([sys.executable, tool, "--registry", ex],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 findings" in r.stdout

    bad = tmp_path / "bad_port.py"
    bad.write_text("def task(ctx):\n"
                   "    yield ctx.aload(0, 64, 8, wait=False)\n"
                   "    yield ctx.cost(1)\n")
    r = subprocess.run([sys.executable, tool, "--json", str(bad)],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 1
    assert json.loads(r.stdout)["findings"][0]["rule"] == "AMI001"
