"""Shared test bootstrap. Runs before any test module imports:

* puts ``src/`` on ``sys.path`` so the suite (and pytest.ini's
  ``filterwarnings`` category resolution) works without PYTHONPATH;
* forces 8 fake CPU devices BEFORE jax initializes, so the in-process
  jit+sharding smoke (tests/test_sharding_smoke.py) can build the same 4x2
  debug mesh the slow system tests drive in subprocesses. Respects an
  existing ``xla_force_host_platform_device_count`` setting.
"""
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()
