"""End-to-end system behaviour: sharded train step on a multi-device debug
mesh (subprocess with forced host device count), dry-run smoke, serve loop.

These run the REAL jit path with in/out shardings on 8 fake CPU devices —
the same code path the 256/512-chip dry-run exercises.
"""
import os
import subprocess
import sys

import pytest

# Each test spawns a subprocess that jit-compiles on 8 fake CPU devices —
# minutes of wall clock; opt-in via `pytest -m slow` (nightly CI job).
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def run_py(code: str, timeout=420) -> str:
    out = subprocess.run([sys.executable, "-c", code], env=ENV, timeout=timeout,
                         capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_debug_mesh():
    """Two train steps on a 4x2 mesh: loss finite and decreasing-ish, state
    sharded per the rules, donation accepted."""
    print(run_py("""
import jax, jax.numpy as jnp
from repro import configs
from repro.launch.mesh import make_debug_mesh
from repro.models import lm
from repro.optim import adamw
from repro.runtime import sharding as shd, steps as steps_mod, hints
from repro.data.pipeline import synthetic_batch

cfg = configs.get_smoke_config("qwen2.5-3b")
shape = configs.ShapeConfig("t", 32, 8, "train")
par = configs.ParallelConfig(remat="full", microbatches=2)
mesh = make_debug_mesh(8)
hints.set_mesh_axes({k: v for k, v in mesh.shape.items()})
opt_cfg = adamw.AdamWConfig(total_steps=4)
with mesh:
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    p_sh = shd.params_shardings(cfg, par, mesh, params)
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(adamw.init_state(params),
                         shd.opt_state_shardings(cfg, par, mesh, params))
    step = jax.jit(steps_mod.make_train_step(cfg, par, opt_cfg),
                   out_shardings=(p_sh, shd.opt_state_shardings(cfg, par, mesh, params), None),
                   donate_argnums=(0, 1))
    losses = []
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, shape, i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
assert all(l == l for l in losses), losses     # no NaN
assert losses[-1] < losses[0] + 0.5, losses    # not diverging
print("LOSSES", losses)
"""))


def test_dryrun_cell_on_debug_mesh():
    """The dry-run builder lowers+compiles on a small mesh in-process."""
    out = run_py("""
import jax
from repro.launch.mesh import make_debug_mesh
from repro.runtime import hints
import repro.launch.dryrun as dr
mesh = make_debug_mesh(8)
hints.set_mesh_axes({k: v for k, v in mesh.shape.items()})
built, reason = dr.build_cell("granite-moe-1b-a400m", "decode_32k", mesh)
fn, args = built
with mesh:
    compiled = fn.lower(*args).compile()
print("MEM", compiled.memory_analysis().temp_size_in_bytes)
""")
    assert "MEM" in out


def test_serve_driver_end_to_end():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2.5-3b",
         "--smoke", "--batch", "2", "--prompt-len", "16", "--max-new", "4"],
        env=ENV, timeout=420, capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "decode:" in out.stdout


def test_train_driver_resume(tmp_path):
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen2.5-3b", "--smoke", "--steps", "4", "--batch", "2",
            "--seq", "32", "--checkpoint-dir", str(tmp_path),
            "--checkpoint-every", "2"]
    out = subprocess.run(args, env=ENV, timeout=420, capture_output=True,
                         text=True, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    out2 = subprocess.run(args + ["--resume", "--steps", "6"], env=ENV,
                          timeout=420, capture_output=True, text=True,
                          cwd=REPO)
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resumed from step 4" in out2.stdout
