"""Epoch-fused command plane: differential + property tests.

The contract under test (TESTING.md "Epoch fusion"): the fused scheduler
(`EpochScheduler`, ``AmuConfig(scheduler="fused")`` — the ``"auto"``
default on the batched engine) stages every port's vector commands for a
whole scheduler epoch and enters the engine/far model ONCE per epoch, yet
stays **bit-identical** to the per-command `BatchScheduler` on the same
engine: same issue/fin trace, same engine stats, same RNG bitstream
consumption (latency draws), same SPM/far-memory bytes, same summary.
The far model's ``issue_epoch`` must likewise be bit-identical to the
per-segment ``issue_batch`` sequence it replaces — including the
mixed-tier reordered path, which vectorizes across regions/links only
when every involved region is unlimited.

`hypothesis` optional — tests/proplib.py falls back to seeded-random
example generation.
"""
import dataclasses

import numpy as np
import pytest
from proplib import given, settings, st

from repro.amu import AmuConfig, AmuSession, REGISTRY, far_region
from repro.configs.base import EngineConfig
from repro.core.coroutines import (AloadNoWait, AloadVec, Aload, Astore,
                                   AstoreNoWait, AstoreVec, AwaitRids,
                                   BatchScheduler, Cost, EpochScheduler, Now,
                                   SpmRead, SpmWrite, WaitUntil)
from repro.core.engine import BatchedAsyncMemoryEngine
from repro.core.farmem import (BimodalTail, FarMemoryConfig, FarMemoryModel,
                               hostjit)

SCHEDS = {"batched": BatchScheduler, "fused": EpochScheduler}


def _tier_regions(table_bytes, shared_link=True, max_inflight=(0, 0, 0)):
    third = (table_bytes // 3) // 8 * 8
    link = "switch" if shared_link else None
    return [far_region("local", 0, third, 0.08,
                       max_inflight=max_inflight[0]),
            far_region("cxl", third, third, 1.0, link=link,
                       max_inflight=max_inflight[1],
                       distribution=BimodalTail(0.1, 8.0)),
            far_region("xswitch", 2 * third, table_bytes - 2 * third, 5.0,
                       link=link, max_inflight=max_inflight[2])]


def _session_pair(wl, *, far=None, vector=False, engine="batched",
                  host_jit=False, **build_kw):
    """Run `wl` under the batched vs fused scheduler; return both capture
    tuples (stats, trace, engine stats, mem)."""
    out = {}
    for sched in ("batched", "fused"):
        cfg = AmuConfig(engine=engine, scheduler=sched, vector=vector,
                        far=far, host_jit=host_jit)
        with AmuSession(cfg) as s:
            stats = s.run(wl, record_trace=True, **build_kw)
            assert stats.verified is True
            out[sched] = (stats, list(s.engine.trace), dict(s.engine.stats),
                          s.engine.mem.copy(), s.engine.spm.copy())
    return out["batched"], out["fused"]


def _assert_pair_identical(a, b):
    (st_a, tr_a, es_a, mem_a, spm_a) = a
    (st_b, tr_b, es_b, mem_b, spm_b) = b
    assert tr_a == tr_b
    assert es_a == es_b
    assert np.array_equal(mem_a, mem_b)
    assert np.array_equal(spm_a, spm_b)
    # dataclass equality skips wall-clock fields (us_per_entry) but engine
    # entry counts intentionally DIFFER between the two loops — compare
    # everything else
    da, db = st_a.to_dict(), st_b.to_dict()
    for k in ("engine_entries", "rows_per_entry"):
        da.pop(k), db.pop(k)
    assert da == db


# =========================================================================
# Workload-level: fused == batched on every registered port
# =========================================================================
@pytest.mark.parametrize("wl", REGISTRY.names())
def test_fused_trace_identical_scalar_port(wl):
    _assert_pair_identical(*_session_pair(wl))


@pytest.mark.parametrize("wl", REGISTRY.vector_names())
def test_fused_trace_identical_vector_port(wl):
    _assert_pair_identical(*_session_pair(wl, vector=True))


@pytest.mark.parametrize("vector", [False, True], ids=["scalar", "vector"])
def test_fused_identical_mixed_tier_gups(vector):
    """Mixed-tier far memory with a shared channel + bimodal tail: the
    reordered fused path must replay per-link injection chains and
    per-region RNG draw order exactly."""
    kw = dict(table_words=2048, updates=512, coroutines=64, distinct=True)
    _assert_pair_identical(*_session_pair(
        "GUPS", far=_tier_regions(2048 * 8), vector=vector, **kw))


@pytest.mark.parametrize("vector", [False, True], ids=["scalar", "vector"])
def test_fused_identical_backpressured_regions(vector):
    """One backpressured tier forces the exact per-segment replay path."""
    kw = dict(table_words=2048, updates=512, coroutines=64, distinct=True)
    _assert_pair_identical(*_session_pair(
        "GUPS", far=_tier_regions(2048 * 8, max_inflight=(0, 8, 4)),
        vector=vector, **kw))


@pytest.mark.parametrize("arrival", ["poisson", "bursty"])
@pytest.mark.parametrize("vector", [False, True], ids=["scalar", "vector"])
def test_fused_identical_paged_kv_serve(arrival, vector):
    """The serving workload mixes WaitUntil sleeps, Now timestamps, scalar
    and vector AMIs against tiered far memory — the hardest fusion case
    (and the regression surface for the deferred token-window bug: scalar
    tokens minted between flushes must advance the epoch window)."""
    from repro.core.serving import serve_regions
    out = {}
    for sched in ("batched", "fused"):
        cfg = AmuConfig(scheduler=sched, far=serve_regions(requests=96),
                        vector=vector)
        with AmuSession(cfg) as s:
            stats = s.run("paged_kv_serve", record_trace=True, requests=96,
                          coroutines=16, arrival=arrival)
            assert stats.verified is True
            out[sched] = (stats, list(s.engine.trace), s.engine.mem.copy())
    (st_a, tr_a, mem_a), (st_b, tr_b, mem_b) = out["batched"], out["fused"]
    assert tr_a == tr_b
    assert np.array_equal(mem_a, mem_b)
    assert st_a.req_mean_us == st_b.req_mean_us
    assert st_a.req_p99_us == st_b.req_p99_us
    assert st_a.req_p999_us == st_b.req_p999_us
    assert st_a.cycles == st_b.cycles


# =========================================================================
# Far-model level: issue_epoch == per-segment issue_batch
# =========================================================================
def _far_pair(cfg, host_jit=False):
    return (FarMemoryModel(dataclasses.replace(cfg)),
            FarMemoryModel(dataclasses.replace(cfg), host_jit=host_jit))


def _random_epochs(rng, n_epochs, addr_space, max_segs=5, max_rows=24,
                   align=8):
    """Random (seg_nows, seg_bounds, sizes, addrs) epoch batches with
    non-decreasing segment times across the whole stream. Requests are
    `align`-aligned with sizes <= align so none straddles a region edge
    (region starts are multiples of 64 in these fixtures)."""
    t = 0.0
    epochs = []
    size_pool = [s for s in (8, 64, 256) if s <= align] or [align]
    for _ in range(n_epochs):
        n_segs = int(rng.integers(1, max_segs + 1))
        ks = rng.integers(1, max_rows + 1, size=n_segs)
        bounds = np.zeros(n_segs + 1, np.int64)
        np.cumsum(ks, out=bounds[1:])
        nows = np.empty(n_segs)
        for s in range(n_segs):
            t += float(rng.uniform(0.0, 400.0))
            nows[s] = t
        n = int(bounds[-1])
        sizes = rng.choice(size_pool, size=n).astype(np.int64)
        addrs = (rng.integers(0, addr_space // align, size=n)
                 * align).astype(np.int64)
        epochs.append((nows, bounds, sizes, addrs))
    return epochs


@pytest.mark.parametrize("variant", ["plain", "jitter", "tail", "inflight"])
def test_issue_epoch_matches_issue_batch_flat(variant):
    kw = {}
    if variant == "jitter":
        kw["jitter_frac"] = 0.3
    elif variant == "tail":
        kw["distribution"] = BimodalTail(0.2, 6.0)
    elif variant == "inflight":
        kw["max_inflight"] = 6
    cfg = FarMemoryConfig.from_latency_us(1.0, **kw)
    a, b = _far_pair(cfg)
    rng = np.random.default_rng(7)
    last = 0.0
    for nows, bounds, sizes, addrs in _random_epochs(rng, 12, 1 << 16,
                                                     align=256):
        ref = np.empty(sizes.size)
        for s in range(nows.size):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            ref[lo:hi] = a.issue_batch(float(nows[s]), sizes[lo:hi],
                                       addrs[lo:hi])
        got = b.issue_epoch(nows, bounds, sizes, addrs)
        assert np.array_equal(ref, got), variant
        last = max(last, float(np.max(ref)))
    assert a.avg_mlp(last + 1.0) == b.avg_mlp(last + 1.0)
    assert a.requests == b.requests and a.bytes_moved == b.bytes_moved


@pytest.mark.parametrize("shared_link", [False, True],
                         ids=["own-links", "shared-channel"])
@pytest.mark.parametrize("inflight", [(0, 0, 0), (0, 8, 0)],
                         ids=["unlimited", "backpressured"])
def test_issue_epoch_matches_issue_batch_regions(shared_link, inflight):
    """Routed mixed-tier epochs: the reordered fused path (all-unlimited)
    and the per-segment replay (any backpressure) are both bit-identical
    to the sequential per-segment issue — latencies, RNG draws, ledgers,
    per-region stats."""
    space = 3 * 4096 * 8
    regions = tuple(r for r in _tier_regions(space, shared_link=shared_link,
                                             max_inflight=inflight))
    cfg = FarMemoryConfig(regions=regions)
    a, b = _far_pair(cfg)
    rng = np.random.default_rng(11)
    last = 0.0
    for nows, bounds, sizes, addrs in _random_epochs(rng, 12, space,
                                                     align=64):
        ref = np.empty(sizes.size)
        for s in range(nows.size):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            ref[lo:hi] = a.issue_batch(float(nows[s]), sizes[lo:hi],
                                       addrs[lo:hi])
        got = b.issue_epoch(nows, bounds, sizes, addrs)
        assert np.array_equal(ref, got)
        last = max(last, float(np.max(ref)))
    assert a.region_stats(last + 1.0) == b.region_stats(last + 1.0)
    assert a.avg_mlp(last + 1.0) == b.avg_mlp(last + 1.0)


def test_host_jit_falls_back_and_stays_identical():
    """`host_jit=True` must be bit-identical to the numpy paths whether or
    not numba is importable (in this container it is not — the knob must
    degrade silently)."""
    cfg = FarMemoryConfig(regions=tuple(_tier_regions(3 * 4096 * 8)))
    a, b = _far_pair(cfg, host_jit=True)
    assert isinstance(hostjit.numba_available(), bool)
    if not hostjit.numba_available():
        assert b._jit_chain is None      # graceful degrade, no import error
    rng = np.random.default_rng(23)
    for nows, bounds, sizes, addrs in _random_epochs(rng, 8, 3 * 4096 * 8,
                                                     align=64):
        ref = np.empty(sizes.size)
        for s in range(nows.size):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            ref[lo:hi] = a.issue_batch(float(nows[s]), sizes[lo:hi],
                                       addrs[lo:hi])
        assert np.array_equal(ref, b.issue_epoch(nows, bounds, sizes, addrs))


def test_host_jit_session_identical():
    a, _ = _session_pair("GUPS", vector=True, table_words=2048, updates=512,
                         coroutines=32)
    b, _ = _session_pair("GUPS", vector=True, host_jit=True,
                         table_words=2048, updates=512, coroutines=32)
    assert a[1] == b[1]                  # trace
    assert a[0].to_dict() == b[0].to_dict()


# =========================================================================
# Scheduler-level properties (proplib): random mixed ports
# =========================================================================
def _drive(sched_kind, tasks_fn, qlen=48, latency_us=1.0):
    cfg = EngineConfig(queue_length=qlen, granularity=8,
                       spm_bytes=64 * 1024, batch_ids=16)
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(latency_us))
    eng = BatchedAsyncMemoryEngine(cfg, far, record_trace=True)
    eng.mem[:8192] = (np.arange(8192) % 251).astype(np.uint8)
    sched = SCHEDS[sched_kind](eng)
    summary = sched.run(tasks_fn())
    eng.drain()
    eng.check_invariants()
    return summary, eng


@given(seed=st.integers(0, 1 << 20), n_tasks=st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_random_mixed_ports_fused_identical(seed, n_tasks):
    """Random interleavings of scalar Aload/Astore, vector gathers/scatters
    (awaited and not), SpmRead/Write and Cost — fused == batched, bit for
    bit. Covers the scalar-between-epochs token-window case by
    construction."""
    def mk_tasks():
        rng = np.random.default_rng(seed)

        def task(tid):
            base = tid * 512
            for _ in range(int(rng.integers(2, 7))):
                op = int(rng.integers(0, 5))
                k = int(rng.integers(1, 9))
                slots = base + rng.permutation(16)[:k] * 8
                addrs = (rng.integers(0, 1000, size=k) * 8)
                if op == 0:
                    if rng.integers(0, 2):       # awaiting scalar load
                        yield Aload(int(slots[0]), int(addrs[0]), 8)
                    else:                        # deferred token + AwaitRids
                        tok = yield AloadNoWait(int(slots[0]),
                                                int(addrs[0]), 8)
                        yield AwaitRids([tok])
                elif op == 1:
                    yield SpmWrite(int(slots[0]),
                                   bytes([tid & 0xFF]) * 8)
                    if rng.integers(0, 2):
                        yield Astore(int(slots[0]), int(addrs[0]), 8)
                    else:
                        tok = yield AstoreNoWait(int(slots[0]),
                                                 int(addrs[0]), 8)
                        yield AwaitRids([tok])
                elif op == 2:
                    yield AloadVec(slots, addrs, 8,
                                   wait=bool(rng.integers(0, 2)))
                elif op == 3:
                    yield SpmWrite(int(slots.min()), bytes(range(128)))
                    yield AstoreVec(slots, addrs, 8, wait=True)
                else:
                    yield Cost(insts=float(rng.integers(0, 300)))
                    yield SpmRead(int(slots[0]), 8)

        return [task(t) for t in range(n_tasks)]

    (sum_a, eng_a) = _drive("batched", mk_tasks)
    (sum_b, eng_b) = _drive("fused", mk_tasks)
    assert eng_a.trace == eng_b.trace
    assert eng_a.stats == eng_b.stats
    assert sum_a == sum_b
    assert np.array_equal(eng_a.spm, eng_b.spm)
    assert np.array_equal(eng_a.mem, eng_b.mem)


@given(seed=st.integers(0, 1 << 20))
@settings(max_examples=20, deadline=None)
def test_waituntil_now_under_fusion(seed):
    """Satellite property: sleepers are never fused past their wake time —
    every post-wake Now() reads >= the requested wake — and the whole
    observable run (summary, Now observations, trace) is bit-identical
    between the fused and per-command schedulers."""
    rng0 = np.random.default_rng(seed)
    wakes = np.sort(rng0.uniform(0.0, 30000.0, size=6))

    def mk_tasks():
        obs = []

        def task(tid, wake):
            yield WaitUntil(wake)
            t0 = yield Now()
            obs.append((tid, t0))
            assert t0 >= wake          # never woken early / fused past wake
            slots = tid * 256 + np.arange(4) * 8
            yield AloadVec(slots, slots, 8, wait=True)
            t1 = yield Now()
            obs.append((tid, t1))

        tasks = [task(i, float(w)) for i, w in enumerate(wakes)]
        return tasks, obs

    captured = {}
    for kind in ("batched", "fused"):
        tasks, obs = mk_tasks()
        summary, eng = _drive(kind, lambda: tasks)
        captured[kind] = (summary, list(obs), list(eng.trace))
    assert captured["batched"] == captured["fused"]


def test_idle_jump_lands_exactly_on_sleeper_wake():
    """With one far-future sleeper and one fast worker, the idle path must
    jump exactly to the sleeper's wake — its first Now() reads exactly W —
    on both scheduler kinds."""
    W = 1.0e6

    def mk_tasks():
        obs = []

        def sleeper():
            yield WaitUntil(W)
            t0 = yield Now()
            obs.append(t0)

        def worker():
            slots = 1024 + np.arange(8) * 8
            yield AloadVec(slots, slots, 8, wait=True)

        return [sleeper(), worker()], obs

    for kind in ("batched", "fused"):
        tasks, obs = mk_tasks()
        _drive(kind, lambda: tasks)
        assert obs == [W]


def test_fused_scheduler_falls_back_on_scalar_engine():
    """EpochScheduler on the oracle engine (no epoch surface) must behave
    exactly like the BatchScheduler it inherits from."""
    out = {}
    for sched in ("batched", "fused"):
        cfg = AmuConfig(engine="scalar", scheduler=sched, vector=True)
        with AmuSession(cfg) as s:
            stats = s.run("GUPS", record_trace=True, table_words=2048,
                          updates=512, coroutines=32)
            assert stats.verified is True
            out[sched] = (stats.to_dict(), list(s.engine.trace))
    assert out["batched"] == out["fused"]


# =========================================================================
# Host-side observability counters (RunStats satellites)
# =========================================================================
def test_engine_entry_counters_collapse_under_fusion():
    kw = dict(table_words=2048, updates=2048, coroutines=32, vec_chunk=32)
    ent = {}
    for sched in ("batched", "fused"):
        with AmuSession(AmuConfig(scheduler=sched, vector=True)) as s:
            stats = s.run("GUPS", **kw)
        assert stats.engine_entries > 0
        assert stats.rows_per_entry > 0
        assert stats.us_per_entry > 0
        ent[sched] = stats
    # one engine entry per epoch beats one per command by a wide margin
    assert ent["fused"].engine_entries < ent["batched"].engine_entries / 2
    assert ent["fused"].rows_per_entry > ent["batched"].rows_per_entry * 2


def test_wall_clock_fields_stay_out_of_model_identity():
    with AmuSession(AmuConfig(vector=True)) as s:
        stats = s.run("GUPS", table_words=2048, updates=512, coroutines=32)
    assert "us_per_entry" not in stats.to_dict()
    assert "us_per_entry" not in stats.keys()
    assert "engine_entries" in stats.keys()
    with pytest.raises(KeyError):
        stats["us_per_entry"]
    assert stats.us_per_entry > 0        # still readable as an attribute
