"""Paged-KV serving workload: arrivals, request latency, pinning.

Pins the serving contracts (TESTING.md "Serving workload"):

* seeded arrival draws (Poisson + bursty) are deterministic, strictly
  increasing, and mean-preserving; the bursty closed-form inversion is
  regression-pinned against the fp-stall seed that hung the old
  incremental loop;
* ``WaitUntil`` wakes a sleeping coroutine exactly at its absolute wake
  time on both scheduler kinds, and a wake time already in the past
  continues immediately (open-loop queueing delay);
* under a fixed scheduler the scalar and batched ENGINES produce identical
  request traces, far-memory bytes, cycle counts — and identical
  per-request completion-latency arrays — for every data plane;
* ``RunStats`` req_* percentiles are populated for the serving workload
  (and None elsewhere), and are stable across ``far.reset_stats()``;
* the synchronous page-fault plane has MLP ~= 1 and the AMI plane beats it
  by a wide margin on mean per-request latency (the smoke-gate floor).
"""
import numpy as np
import pytest

from repro.amu import AmuConfig, AmuSession, ctx
from repro.core.coroutines import SCHEDULER_KINDS
from repro.core.engine import make_engine
from repro.core.farmem import FarMemoryConfig, FarMemoryModel
from repro.core.serving import (build_paged_kv_serve, bursty_arrivals,
                                poisson_arrivals, serve_regions)


# ---------------------------------------------------------------- arrivals
def test_poisson_arrivals_deterministic_and_monotone():
    a = poisson_arrivals(3, 256, 2.0)
    b = poisson_arrivals(3, 256, 2.0)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0) and a[0] > 0
    assert not np.array_equal(a, poisson_arrivals(4, 256, 2.0))
    # rate is honoured in the mean (3000 cycles per us)
    rate = 256 / (a[-1] / 3e3)
    assert 1.5 < rate < 2.6, rate


def test_bursty_arrivals_deterministic_mean_preserving_and_bursty():
    a = bursty_arrivals(3, 4096, 2.0)
    np.testing.assert_array_equal(a, bursty_arrivals(3, 4096, 2.0))
    assert np.all(np.diff(a) >= 0)
    # mean-preserving: long-run rate matches the base rate
    rate = 4096 / (a[-1] / 3e3)
    assert 1.8 < rate < 2.2, rate
    # bursty: the duty fraction of each period carries most arrivals
    phase = (a / 3e3) % 8.0
    frac = float(np.mean(phase < 0.2 * 8.0))
    assert frac > 0.6, frac                      # duty is 0.2


def test_bursty_arrivals_fp_stall_regression():
    """Seed/rate pair whose 17th draw landed within one ulp of a segment
    boundary and hung the old incremental inversion forever."""
    a = bursty_arrivals(101, 96, 2.0)
    assert a.shape == (96,) and np.all(np.diff(a) >= 0)


def test_bursty_arrivals_rejects_degenerate_square_wave():
    with pytest.raises(ValueError, match="burst"):
        bursty_arrivals(0, 8, 2.0, burst_mult=4.0, duty=0.25)
    with pytest.raises(ValueError, match="duty"):
        bursty_arrivals(0, 8, 2.0, duty=1.5)


# ------------------------------------------------------- WaitUntil / Now
@pytest.mark.parametrize("kind", sorted(SCHEDULER_KINDS))
def test_wait_until_wakes_exactly(kind):
    inst = build_paged_kv_serve(requests=4, coroutines=2)
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(1.0))
    eng = make_engine("batched", inst.engine_config, far, inst.mem)
    wakes = {}

    def sleeper(i, t):
        yield ctx.wait_until(t)
        wakes[i] = (yield ctx.now())

    sched = SCHEDULER_KINDS[kind](eng)
    sched.run([sleeper(0, 5000.0), sleeper(1, 12345.5), sleeper(2, 100.0)])
    assert wakes[0] == 5000.0 and wakes[1] == 12345.5 and wakes[2] == 100.0


@pytest.mark.parametrize("kind", sorted(SCHEDULER_KINDS))
def test_wait_until_in_the_past_continues_immediately(kind):
    inst = build_paged_kv_serve(requests=4, coroutines=2)
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(1.0))
    eng = make_engine("batched", inst.engine_config, far, inst.mem)
    seen = {}

    def task():
        yield ctx.wait_until(9000.0)             # advance the clock
        yield ctx.wait_until(10.0)               # long past: free continue
        seen["t"] = (yield ctx.now())

    sched = SCHEDULER_KINDS[kind](eng)
    sched.run([task()])
    assert seen["t"] == 9000.0


# ----------------------------------------------- engine pinning contract
def _lat_of(session):
    return session.instance.request_latency_cycles.copy()


@pytest.mark.parametrize("plane,vector", [("ami", False), ("ami", True),
                                          ("sync", False)])
def test_serving_engines_trace_and_latency_identical(plane, vector):
    """Scalar vs batched ENGINE under the fixed scalar scheduler: identical
    request trace, far-memory bytes, cycles, and per-request latencies."""
    results = []
    for engine in ("scalar", "batched"):
        cfg = AmuConfig(engine=engine, scheduler="scalar", vector=vector,
                        far=serve_regions())
        with AmuSession(cfg) as s:
            st = s.run("paged_kv_serve", record_trace=True,
                       data_plane=plane)
            assert st.verified
            results.append((list(s.engine.trace), s.engine.mem.copy(),
                            st.cycles, _lat_of(s), st))
    tr_a, mem_a, cyc_a, lat_a, st_a = results[0]
    tr_b, mem_b, cyc_b, lat_b, st_b = results[1]
    assert tr_a == tr_b
    assert np.array_equal(mem_a, mem_b)
    assert cyc_a == cyc_b
    np.testing.assert_array_equal(lat_a, lat_b)
    assert (st_a.req_p50_us, st_a.req_p99_us, st_a.req_p999_us) == \
        (st_b.req_p50_us, st_b.req_p99_us, st_b.req_p999_us)


def test_serving_latencies_nonnegative_and_fields_populated():
    with AmuSession(AmuConfig(engine="batched", far=serve_regions())) as s:
        st = s.run("paged_kv_serve")
    assert st.req_count == 96
    assert 0 < st.req_p50_us <= st.req_p99_us <= st.req_p999_us
    assert st.req_mean_us > 0
    # non-request workloads carry no req_* stats
    with AmuSession(AmuConfig(engine="batched")) as s:
        st2 = s.run("GUPS")
    assert st2.req_count is None and st2.req_p99_us is None


def test_serving_percentiles_stable_across_reset_stats():
    """prepare -> warmup traffic -> reset_stats -> execute reproduces the
    plain run bit-for-bit, req_* fields included (measured-phase idiom)."""
    cfg = AmuConfig(engine="batched", scheduler="scalar",
                    far=serve_regions())
    with AmuSession(cfg) as s:
        baseline = s.run("paged_kv_serve")
    with AmuSession(cfg) as s:
        s.prepare("paged_kv_serve")
        s.far.issue_batch(0.0, np.full(16, 256),
                          np.arange(16, dtype=np.int64) * 256)  # warmup
        s.far.reset_stats()
        measured = s.execute()
    assert measured == baseline


def test_sync_baseline_no_mlp_and_ami_speedup():
    cfg = AmuConfig(engine="batched", far=serve_regions())
    with AmuSession(cfg) as s:
        sync = s.run("paged_kv_serve", data_plane="sync")
    with AmuSession(cfg) as s:
        ami = s.run("paged_kv_serve")
    assert sync.verified and ami.verified
    assert sync.mlp < 1.2                        # one blocking fetch at a time
    assert ami.mlp > 3.0
    assert sync.req_mean_us / ami.req_mean_us > 5.0


def test_serving_verifies_on_flat_model_and_bursty():
    with AmuSession(AmuConfig(engine="batched")) as s:
        st = s.run("paged_kv_serve", arrival="bursty")
    assert st.verified and st.req_count == 96
