"""Per-kernel correctness: shape/dtype sweeps against the ref.py oracles,
all in interpret mode (CPU validates the TPU kernel bodies)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.async_gather import async_gather
from repro.kernels.async_scatter import async_scatter
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.stream_triad import stream_triad

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- async_gather
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("n,d,m,bm,k", [
    (64, 128, 256, 128, 8),
    (512, 256, 128, 64, 4),
    (33, 128, 64, 32, 2),
    (1024, 512, 512, 256, 16),
])
def test_async_gather(n, d, m, bm, k, dtype):
    if dtype == jnp.int32:
        table = jnp.array(RNG.integers(0, 1 << 20, (n, d)), dtype)
    else:
        table = jnp.array(RNG.standard_normal((n, d)), dtype)
    idx = jnp.array(RNG.integers(0, n, m), jnp.int32)
    out = async_gather(table, idx, block_m=bm, num_slots=k, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.gather_ref(table, idx)))


# ------------------------------------------------------------ async_scatter
@pytest.mark.parametrize("n,d,m,bm,k", [
    (64, 128, 256, 128, 8),   # heavy conflicts
    (8, 128, 64, 32, 4),      # extreme conflicts
    (1024, 256, 128, 128, 8), # sparse
    (16, 8, 128, 64, 8),
])
def test_async_scatter_add(n, d, m, bm, k):
    table = jnp.array(RNG.standard_normal((n, d)), jnp.float32)
    idx = jnp.array(RNG.integers(0, n, m), jnp.int32)
    upd = jnp.array(RNG.standard_normal((m, d)), jnp.float32)
    out = async_scatter(table, idx, upd, op="add", block_m=bm, num_slots=k,
                        interpret=True)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ref.scatter_update_ref(table, idx, upd, "add")),
        atol=1e-4, rtol=1e-4)


def test_async_scatter_xor_gups():
    """GUPS semantics: integer xor RMW with many conflicts."""
    n, d, m = 32, 8, 256
    table = jnp.array(RNG.integers(0, 1 << 30, (n, d)), jnp.int32)
    idx = jnp.array(RNG.integers(0, n, m), jnp.int32)
    upd = jnp.array(RNG.integers(0, 1 << 30, (m, d)), jnp.int32)
    out = async_scatter(table, idx, upd, op="xor", block_m=128, num_slots=8,
                        interpret=True)
    expect = ref.scatter_update_ref(table, idx, upd, "xor")
    assert bool(jnp.all(out == expect))


@pytest.mark.slow
def test_async_scatter_fuzz():
    rng = np.random.default_rng(7)
    for _ in range(10):
        n = int(rng.integers(4, 128))
        bm = int(rng.choice([16, 64]))
        m = bm * int(rng.integers(1, 4))
        k = int(rng.choice([2, 4, 8]))
        table = jnp.array(rng.standard_normal((n, 32)), jnp.float32)
        idx = jnp.array(rng.integers(0, n, m), jnp.int32)
        upd = jnp.array(rng.standard_normal((m, 32)), jnp.float32)
        out = async_scatter(table, idx, upd, op="add", block_m=bm,
                            num_slots=k, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ref.scatter_update_ref(table, idx, upd, "add")),
            atol=1e-4, rtol=1e-4)


# -------------------------------------------------------------- stream_triad
@pytest.mark.parametrize("n,block", [(4096, 512), (8192, 1024), (512, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_triad(n, block, dtype):
    b = jnp.array(RNG.standard_normal(n), dtype)
    c = jnp.array(RNG.standard_normal(n), dtype)
    out = stream_triad(b, c, 3.0, block=block, interpret=True)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.triad_ref(b, c, 3.0),
                                          np.float32), atol=tol, rtol=tol)


# ----------------------------------------------------------- flash_attention
@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", [
    (2, 4, 2, 256, 64, 64, 64),
    (1, 8, 1, 128, 128, 128, 128),   # MQA
    (2, 2, 2, 512, 32, 128, 64),     # MHA, rectangular blocks
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention(b, hq, hkv, s, d, bq, bk, window):
    q = jnp.array(RNG.standard_normal((b, hq, s, d)), jnp.float32) * 0.3
    k = jnp.array(RNG.standard_normal((b, hkv, s, d)), jnp.float32) * 0.3
    v = jnp.array(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    q = jnp.array(RNG.standard_normal((1, 4, 128, 64)), jnp.bfloat16) * 0.3
    k = jnp.array(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16) * 0.3
    v = jnp.array(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=3e-2, rtol=3e-2)


# ----------------------------------------------------------- paged_attention
@pytest.mark.parametrize("b,hq,hkv,t,d,page", [
    (3, 8, 2, 1024, 64, 256),
    (1, 4, 4, 512, 128, 512),    # MHA
    (2, 16, 2, 2048, 64, 512),   # deep GQA
])
def test_paged_attention(b, hq, hkv, t, d, page):
    q = jnp.array(RNG.standard_normal((b, hq, d)), jnp.float32) * 0.3
    kc = jnp.array(RNG.standard_normal((b, t, hkv, d)), jnp.float32) * 0.3
    vc = jnp.array(RNG.standard_normal((b, t, hkv, d)), jnp.float32)
    lens = jnp.array(RNG.integers(1, t + 1, b), jnp.int32)
    out = paged_attention(q, kc, vc, lens, page=page, interpret=True)
    expect = ref.paged_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=1e-4)


# ------------------------------------------------------------- ops wrappers
def test_ops_padding_paths():
    table = jnp.array(RNG.standard_normal((100, 64)), jnp.float32)
    idx = jnp.array(RNG.integers(0, 100, 37), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ops.gather(table, idx, block_m=16)),
        np.asarray(ref.gather_ref(table, idx)))
    upd = jnp.array(RNG.standard_normal((37, 64)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.scatter_update(table, idx, upd, block_m=16,
                                      num_slots=4)),
        np.asarray(ref.scatter_update_ref(table, idx, upd)), atol=1e-4)
    b = jnp.array(RNG.standard_normal(1000), jnp.float32)
    c = jnp.array(RNG.standard_normal(1000), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.triad(b, c, 2.5, block=512)),
                               np.asarray(ref.triad_ref(b, c, 2.5)),
                               atol=1e-6)
