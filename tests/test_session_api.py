"""The unified `repro.amu` session API.

Covers the four redesign pieces plus their compatibility story:

* `AmuConfig` — validation, `derive`, knob resolution (scheduler "auto",
  SPM budget, DMA-mode batch_ids).
* `AmuSession` — lifecycle (engine/far/scheduler/instance exposure, context
  manager), `RunStats` mapping protocol, and choreography identity: the
  session must produce exactly the trace the old hand-rolled
  build-engine-build-scheduler-run-drain sequence produced.
* the `@workload` registry — capabilities, custom registration, the Port
  protocol.
* `AcquireVec`/`ReleaseVec` — one-hop vector locking: mutual exclusion, FIFO
  hand-off, mid-vector continuation, no lost waiters (both schedulers).
* the scalar `Scheduler`'s exact-wake idle drain — pinned bit-identical
  (summary + engine trace + engine stats) to the old single-step idle path.
"""
import dataclasses

import numpy as np
import pytest

from repro.amu import (REGISTRY, AmuConfig, AmuSession, Port,
                       WorkloadRegistry, ctx, far_config, workload)
from repro.configs.base import EngineConfig
from repro.core.coroutines import (Acquire, AcquireVec, Aload, AloadVec,
                                   AwaitRid, BatchScheduler, Cost,
                                   DeadlockError, Release, ReleaseVec,
                                   Scheduler, SpmRead, SpmWrite)
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import BatchedAsyncMemoryEngine, make_engine
from repro.core.farmem import FarMemoryConfig, FarMemoryModel
from repro.core.workloads import WorkloadInstance, build_gups


# =========================================================================
# AmuConfig
# =========================================================================
def test_config_validation():
    with pytest.raises(KeyError):
        AmuConfig(engine="warp")
    with pytest.raises(KeyError):
        AmuConfig(scheduler="warp")
    with pytest.raises(ValueError):
        AmuConfig(pipeline_k=0)
    with pytest.raises(ValueError):
        AmuConfig(latency_us=0.0)
    with pytest.raises(ValueError):
        AmuConfig(spm_bytes=-1)
    with pytest.raises(ValueError):
        AmuConfig(seed=-1)


def test_config_derive_revalidates_and_is_frozen():
    cfg = AmuConfig(engine="batched", latency_us=0.5)
    hot = cfg.derive(latency_us=5.0, vector=True)
    assert (hot.latency_us, hot.vector) == (5.0, True)
    assert (cfg.latency_us, cfg.vector) == (0.5, False)   # original intact
    with pytest.raises(KeyError):
        cfg.derive(engine="warp")
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.engine = "scalar"


def test_config_resolution():
    port_cfg = EngineConfig(queue_length=64, granularity=8)
    cfg = AmuConfig(engine="scalar", spm_bytes=1 << 17, dma_mode=True)
    ecfg = cfg.resolve_engine_config(port_cfg)
    assert ecfg.spm_bytes == 1 << 17
    assert ecfg.batch_ids == 1                 # DMA-mode ablation
    assert ecfg.queue_length == 64             # port sizing preserved
    assert AmuConfig(scheduler="auto", engine="batched").scheduler_kind \
        == "fused"                             # epoch-fused on SoA engine
    assert AmuConfig(scheduler="auto", engine="scalar").scheduler_kind \
        == "scalar"
    assert AmuConfig(engine="batched",
                     scheduler="scalar").scheduler_kind == "scalar"
    assert AmuConfig(engine="batched",
                     scheduler="batched").scheduler_kind == "batched"
    # explicit FarMemoryConfig replaces the whole operating point
    far = far_config(2.0, max_inflight=7)
    assert AmuConfig(far=far).resolve_far_config() is far
    assert AmuConfig(max_inflight=9).resolve_far_config().max_inflight == 9
    assert AmuConfig(llvm_mode=True).cost_model().switch_insts == 20


def test_config_far_rejects_shadowed_latency_knobs():
    """far= replaces the operating point wholesale: deriving latency_us (or
    max_inflight) on a far-bearing config must ERROR, never be silently
    ignored — a sweep built that way would record mislabeled points."""
    far = far_config(1.0, max_inflight=8)
    cfg = AmuConfig(far=far)
    with pytest.raises(ValueError):
        cfg.derive(latency_us=5.0)
    with pytest.raises(ValueError):
        AmuConfig(far=far, max_inflight=8)
    with pytest.raises(ValueError):
        AmuConfig(max_inflight=-1)


# =========================================================================
# AmuSession lifecycle + RunStats
# =========================================================================
def test_session_runs_named_workload_and_exposes_stack():
    with AmuSession(AmuConfig(engine="batched", latency_us=1.0)) as s:
        stats = s.run("GUPS")
        assert stats.verified and stats.workload == "GUPS"
        assert isinstance(s.engine, BatchedAsyncMemoryEngine)
        assert isinstance(s.scheduler, BatchScheduler)
        assert s.far.requests == stats.requests
        assert s.instance.name == "GUPS"
    assert s.engine is None                    # closed on exit


def test_session_scheduler_override():
    with AmuSession(AmuConfig(engine="batched", scheduler="scalar")) as s:
        assert s.run("GUPS").verified
        assert isinstance(s.engine, BatchedAsyncMemoryEngine)
        assert type(s.scheduler) is Scheduler


def test_run_stats_mapping_protocol():
    stats = AmuSession(AmuConfig(engine="scalar")).run("GUPS")
    assert stats["us"] == stats.us and stats["mlp"] == stats.mlp
    assert "requests" in stats and "nonsense" not in stats
    assert dict(stats) == stats.to_dict()
    assert stats.get("nonsense", 42) == 42
    with pytest.raises(KeyError):
        stats["nonsense"]
    # method names are NOT keys (old plain-dict semantics)
    assert "keys" not in stats and stats.get("to_dict") is None
    with pytest.raises(KeyError):
        stats["keys"]


def test_session_build_kwargs_reach_builder():
    with AmuSession(AmuConfig(engine="batched")) as s:
        stats = s.run("GUPS", table_words=1024, updates=256, coroutines=16)
        assert stats.units == 256 and stats.verified


def test_prepare_execute_split_and_vector_stamp():
    """prepare() builds the stack without running (benchmarks time execute()
    alone), and registry-built instances carry which port was selected —
    the stamp, not the session config, labels the stats."""
    inst = REGISTRY.build("GUPS", 0, vector=True, table_words=1024,
                          updates=256, coroutines=8)
    assert inst.vector is True
    assert REGISTRY.build("GUPS", 0).vector is False
    with AmuSession(AmuConfig(engine="batched")) as s:   # cfg.vector=False
        s.prepare(inst)
        assert s.engine is not None and s.far.requests == 0   # not yet run
        stats = s.execute()
        assert stats.vector is True          # the built port wins over config
        assert stats.verified and stats.requests == s.far.requests
    # raw builder output (no registry involved) is labeled truthfully too:
    # WorkloadInstance itself records which port was built
    raw = build_gups(0, table_words=1024, updates=256, coroutines=8,
                     vector=True)
    assert AmuSession(AmuConfig(engine="batched")).run(raw).vector is True
    with pytest.raises(RuntimeError):
        AmuSession(AmuConfig()).execute()    # nothing prepared


def test_session_runs_prebuilt_port():
    inst = build_gups(0, table_words=1024, updates=256, coroutines=16)
    with AmuSession(AmuConfig(engine="scalar")) as s:
        assert s.run(inst).verified
        assert s.instance is inst


def test_session_choreography_identical_to_manual_stack():
    """The session must reproduce the old hand-rolled choreography exactly:
    same engine trace, same far-memory bytes, same timing."""
    for wl, vector in (("GUPS", False), ("HJ", True)):
        kw = {"vector": True} if vector else {}
        inst = REGISTRY[wl].build(0, **kw)
        far = FarMemoryModel(far_config(1.0))
        eng = make_engine("scalar", inst.engine_config, far, inst.mem,
                          record_trace=True)
        disamb = CuckooAddressSet() if inst.disambiguation else None
        sched = Scheduler(eng, disambiguator=disamb)
        sched.run(inst.tasks)
        eng.drain()
        manual = sched.summary()

        with AmuSession(AmuConfig(engine="scalar", vector=vector)) as s:
            stats = s.run(wl, record_trace=True)
            assert s.engine.trace == eng.trace, wl
            assert np.array_equal(s.engine.mem, eng.mem), wl
        assert stats.cycles == manual["cycles"], wl
        assert stats.insts == manual["insts"], wl


# =========================================================================
# Registry + Port protocol
# =========================================================================
def test_registry_capabilities_cover_builtin_workloads():
    assert sorted(REGISTRY.names()) == ["BFS", "BS", "GUPS", "HJ", "HPCG",
                                        "HT", "IS", "LL", "Redis", "SL",
                                        "STREAM", "paged_kv_serve"]
    assert sorted(REGISTRY.vector_names()) == sorted(REGISTRY.names())
    for name in ("HJ", "HT", "Redis"):
        assert REGISTRY[name].pipelined and REGISTRY[name].locked
    assert REGISTRY["STREAM"].llvm_defaults == {"block_doubles": 1}
    assert REGISTRY["BFS"].frontier
    assert REGISTRY["GUPS"].distinct and REGISTRY["Redis"].distinct
    assert REGISTRY["paged_kv_serve"].request_level
    with pytest.raises(KeyError):
        REGISTRY["nope"]


def test_registry_build_honours_capabilities():
    # vector=True on a vector-capable workload picks the vector port
    # (fewer, wider coroutines); pipeline_k reaches only pipelined ports
    scalar = REGISTRY.build("LL")
    vec = REGISTRY.build("LL", vector=True, pipeline_k=4)
    assert len(vec.tasks) < len(scalar.tasks)
    # llvm_mode rebuilds STREAM at 8B granularity (scalar port)
    llvm = REGISTRY.build("STREAM", llvm_mode=True, vector=True)
    assert llvm.engine_config.granularity == 8
    # pipeline_k silently skips non-pipelined ports instead of TypeError
    assert REGISTRY.build("GUPS", vector=True, pipeline_k=4).name == "GUPS"


def test_custom_workload_registration_end_to_end():
    reg = WorkloadRegistry()

    @workload("COPY8", registry=reg, description="8B far-to-far copies")
    def build_copy(seed: int = 0, words: int = 64):
        rng = np.random.default_rng(seed)
        src = rng.integers(0, 1 << 62, size=words, dtype=np.uint64)
        mem = np.concatenate([src, np.zeros(words, np.uint64)]) \
            .view(np.uint8).copy()

        def task(lo, hi):
            for i in range(lo, hi):
                yield ctx.aload(0, i * 8, 8)
                yield ctx.astore(0, (words + i) * 8, 8)

        def verify(m):
            return bool(np.array_equal(m.view(np.uint64)[words:], src))

        return WorkloadInstance("COPY8", mem, [task(0, words)], words,
                                EngineConfig(queue_length=32, granularity=8),
                                verify)

    assert isinstance(build_copy(0), Port)       # structural protocol
    with pytest.raises(ValueError):              # duplicate name rejected
        reg.register(reg["COPY8"])
    for engine in ("scalar", "batched"):
        with AmuSession(AmuConfig(engine=engine), registry=reg) as s:
            assert s.run("COPY8").verified


# =========================================================================
# AcquireVec / ReleaseVec
# =========================================================================
@pytest.mark.parametrize("sched_cls", [Scheduler, BatchScheduler])
def test_acquire_vec_mutual_exclusion_no_lost_waiters(sched_cls):
    """Overlapping ascending lock sets across many tasks: every task
    completes, and no two tasks ever hold a block concurrently."""
    rng = np.random.default_rng(7)
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(1.0))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=64, granularity=8), far)
    held, done = set(), []

    def task(i, blocks):
        addrs = sorted(b * 0x1000 for b in blocks)
        yield AcquireVec(addrs)
        for a in addrs:
            assert a not in held, (i, a)
            held.add(a)
        yield Aload(0, 8 * (i % 64), 8)          # hold across a far access
        for a in addrs:
            held.remove(a)
        yield ReleaseVec(addrs)
        done.append(i)

    tasks = [task(i, set(rng.choice(4, size=rng.integers(1, 4) + 0,
                                    replace=False).tolist()))
             for i in range(24)]
    sched_cls(eng, disambiguator=CuckooAddressSet()).run(tasks)
    assert sorted(done) == list(range(24))
    assert not held


@pytest.mark.parametrize("sched_cls", [Scheduler, BatchScheduler])
def test_acquire_vec_mid_vector_continuation(sched_cls):
    """A holder of the MIDDLE block of a vector set: the vector task
    acquires a prefix, suspends, and continues from the hand-off without
    re-acquiring what it already holds."""
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(1.0))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=16, granularity=8), far)
    events = []

    def holder():
        yield Acquire(0x2000)
        events.append("holder-acquired")
        yield Aload(0, 0, 8)
        events.append("holder-releasing")
        yield Release(0x2000)

    def vec_task():
        yield Cost(insts=1000)                   # let the holder go first
        yield AcquireVec([0x1000, 0x2000, 0x3000])
        events.append("vec-acquired")
        yield ReleaseVec([0x1000, 0x2000, 0x3000])

    sched_cls(eng, disambiguator=CuckooAddressSet()).run(
        [holder(), vec_task()])
    assert events == ["holder-acquired", "holder-releasing", "vec-acquired"]


@pytest.mark.parametrize("sched_cls", [Scheduler, BatchScheduler])
def test_release_vec_wakes_scalar_acquire_waiter(sched_cls):
    """FIFO hand-off works across the scalar/vector lock command boundary."""
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(0.5))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=16, granularity=8), far)
    order = []

    def vec_task():
        yield AcquireVec([0x1000, 0x2000])
        order.append("vec")
        yield Aload(0, 0, 8)
        yield ReleaseVec([0x1000, 0x2000])

    def scalar_task():
        yield Cost(insts=500)                    # arrive second
        yield Acquire(0x2000)
        order.append("scalar")
        yield Release(0x2000)

    sched_cls(eng, disambiguator=CuckooAddressSet()).run(
        [vec_task(), scalar_task()])
    assert order == ["vec", "scalar"]


def test_acquire_vec_is_one_generator_hop():
    """The whole lock set costs one coroutine round trip: a K-lock batch
    yields exactly once for AcquireVec and once for ReleaseVec."""
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=16, granularity=8),
        FarMemoryModel(FarMemoryConfig.from_latency_us(0.1)))
    hops = []

    def counted(gen):
        for cmd in gen:
            hops.append(type(cmd).__name__)
            yield cmd

    def task():
        yield AcquireVec([0x1000, 0x2000, 0x3000, 0x4000])
        yield ReleaseVec([0x1000, 0x2000, 0x3000, 0x4000])

    BatchScheduler(eng, disambiguator=CuckooAddressSet()).run(
        [counted(task())])
    assert hops == ["AcquireVec", "ReleaseVec"]


def test_acquire_vec_charges_per_block_disamb_work():
    """Cost model: one hop, but cuckoo probe/insert work scales with the
    lock-set size (disamb_cycles grows with K)."""
    def run_locks(k):
        eng = BatchedAsyncMemoryEngine(
            EngineConfig(queue_length=16, granularity=8),
            FarMemoryModel(FarMemoryConfig.from_latency_us(0.1)))

        def task():
            addrs = [0x1000 * (i + 1) for i in range(k)]
            yield AcquireVec(addrs)
            yield ReleaseVec(addrs)

        sched = Scheduler(eng, disambiguator=CuckooAddressSet())
        sched.run([task()])
        return sched.disamb_cycles

    assert run_locks(8) > 3 * run_locks(2)


# =========================================================================
# Scalar Scheduler exact-wake idle drain: pinned to single-stepping
# =========================================================================
class _SingleStepScheduler(Scheduler):
    """The pre-planning idle path (regression oracle): advance to the next
    completion, one full runtime-loop turn per completion."""

    def _idle_until_completion(self):
        if not (self._waiting_count() or self._alloc_parked):
            raise DeadlockError("live tasks but none ready/waiting")
        next_done = self.engine.next_completion_time
        if next_done is None:
            if self.engine.finished_pending:
                return
            raise DeadlockError("waiting but nothing outstanding")
        self.t = max(self.t, next_done)
        self.engine.advance(self.t)


_SMALL = {
    "GUPS": dict(table_words=2048, updates=512, coroutines=64),
    "STREAM": dict(n=8192, coroutines=8),
    "BS": dict(n_elems=2048, searches=96, coroutines=48),
    "HJ": dict(build_keys=512, buckets=512, probes=192, coroutines=48),
    "SL": dict(n_keys=256, lookups=96, coroutines=24),
}


def _scalar_run(sched_cls, wl, *, vector=False, max_inflight=0, qlen=None,
                latency_us=1.0):
    kw = dict(_SMALL.get(wl, {}))
    if vector:
        kw["vector"] = True
    inst = REGISTRY[wl].build(0, **kw)
    ecfg = inst.engine_config
    if qlen:
        ecfg = dataclasses.replace(ecfg, queue_length=qlen)
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(
        latency_us, max_inflight=max_inflight))
    eng = make_engine("scalar", ecfg, far, inst.mem, record_trace=True)
    disamb = CuckooAddressSet() if inst.disambiguation else None
    sched = sched_cls(eng, disambiguator=disamb)
    sched.run(inst.tasks)
    eng.drain()
    assert inst.verify(eng.mem)
    return sched.summary(), eng


@pytest.mark.parametrize("wl", ["GUPS", "STREAM", "BS", "HJ", "SL"])
def test_wake_planned_idle_bit_identical(wl):
    new_sum, new_eng = _scalar_run(Scheduler, wl)
    old_sum, old_eng = _scalar_run(_SingleStepScheduler, wl)
    assert new_sum == old_sum, wl
    assert new_eng.trace == old_eng.trace, wl
    assert new_eng.stats == old_eng.stats, wl
    assert np.array_equal(new_eng.mem, old_eng.mem)


@pytest.mark.parametrize(
    "kw", [dict(vector=True), dict(max_inflight=8), dict(latency_us=5.0),
           dict(vector=True, qlen=16)],         # qlen=16: parked-retry path
    ids=["vector", "backpressure", "high-latency", "id-exhaustion"])
def test_wake_planned_idle_bit_identical_hard_modes(kw):
    new_sum, new_eng = _scalar_run(Scheduler, "GUPS", **kw)
    old_sum, old_eng = _scalar_run(_SingleStepScheduler, "GUPS", **kw)
    assert new_sum == old_sum
    assert new_eng.trace == old_eng.trace
    assert new_eng.stats == old_eng.stats


def test_builder_knob_signature_byte_identical():
    """Old-style direct builder calls (positional seed + knobs) run through
    the session identically to a registry build with the same knobs."""
    old_inst = build_gups(0, table_words=1024, updates=256, coroutines=16,
                          vector=True, distinct=True)
    new_inst = REGISTRY.build("GUPS", 0, vector=True, table_words=1024,
                              updates=256, coroutines=16, distinct=True)
    runs = []
    for inst in (old_inst, new_inst):
        with AmuSession(AmuConfig(engine="batched",
                                  vector=True)) as s:
            stats = s.run(inst, record_trace=True)
            runs.append((stats.to_dict(), s.engine.trace,
                         s.engine.mem.copy()))
    (st_a, tr_a, mem_a), (st_b, tr_b, mem_b) = runs
    assert st_a == st_b and tr_a == tr_b
    assert np.array_equal(mem_a, mem_b)


# =========================================================================
# Command facade lowers 1:1
# =========================================================================
def test_ctx_facade_lowers_to_command_objects():
    assert ctx.aload(8, 64, 16) == Aload(8, 64, 16)
    assert type(ctx.aload(8, 64, 16, wait=False)).__name__ == "AloadNoWait"
    assert type(ctx.astore(0, 0)).__name__ == "Astore"
    assert type(ctx.astore(0, 0, wait=False)).__name__ == "AstoreNoWait"
    v = ctx.aload_vec([0, 8], [64, 128], 8)
    assert isinstance(v, AloadVec) and v.wait is True
    assert ctx.astore_vec([0], [8], 8, wait=False).wait is False
    assert ctx.await_rid(3) == AwaitRid(3)
    assert ctx.await_rids([1, 2]).rids == (1, 2)
    assert ctx.acquire(64) == Acquire(64)
    assert ctx.release(64) == Release(64)
    assert isinstance(ctx.acquire_vec([0, 64]), AcquireVec)
    assert isinstance(ctx.release_vec([0, 64]), ReleaseVec)
    assert ctx.spm_read(0, 8) == SpmRead(0, 8)
    assert isinstance(ctx.spm_write(0, b"x"), SpmWrite)
    assert ctx.cost(insts=3, cycles=1.5) == Cost(3, 1.5)
