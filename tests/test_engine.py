"""Engine/disambiguation invariants: unit + hypothesis property tests.

`hypothesis` is optional: tests/proplib.py falls back to seeded-random
example generation when it is not installed (see requirements-dev.txt).
"""
import numpy as np
import pytest
from proplib import given, settings, st

from repro.configs.base import EngineConfig
from repro.core.coroutines import (Aload, AloadNoWait, AwaitRid, Cost,
                                   Scheduler, SpmRead)
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import AsyncMemoryEngine, SpmOverflow
from repro.core.farmem import FarMemoryConfig, FarMemoryModel


def make_engine(queue_length=16, granularity=8, latency_us=1.0):
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(latency_us))
    return AsyncMemoryEngine(
        EngineConfig(queue_length=queue_length, granularity=granularity), far)


# ----------------------------------------------------------------- ID pool
def test_alloc_failure_returns_zero():
    eng = make_engine(queue_length=4)
    rids = [eng.aload(0, 0) for _ in range(5)]
    assert all(r > 0 for r in rids[:4])
    assert rids[4] == 0                       # Table 1: Rd=0 on alloc failure
    eng.check_invariants()


def test_getfin_zero_when_nothing_finished():
    eng = make_engine()
    assert eng.getfin() == 0                  # failure code
    eng.aload(0, 0)
    assert eng.getfin() == 0                  # not completed yet (t=0)
    eng.drain()
    assert eng.getfin() > 0


def test_data_movement_roundtrip():
    eng = make_engine()
    eng.mem[100:108] = np.arange(8, dtype=np.uint8)
    rid = eng.aload(0, 100, 8)
    eng.drain()
    assert eng.getfin() == rid
    assert bytes(eng.spm_read(0, 8)) == bytes(range(8))
    eng.spm_write(8, bytes([9] * 8))
    eng.astore(8, 200, 8)
    eng.drain()
    eng.getfin()
    assert bytes(eng.mem[200:208]) == bytes([9] * 8)
    eng.check_invariants()


def test_spm_bounds_enforced():
    eng = make_engine()
    with pytest.raises(SpmOverflow):
        eng.aload(eng.spm_data_bytes - 4, 0, 8)
    with pytest.raises(SpmOverflow):
        EngineConfig(queue_length=8192, spm_bytes=64 * 1024)          # meta > spm
        AsyncMemoryEngine(EngineConfig(queue_length=8192,
                                       spm_bytes=64 * 1024))


@given(ops=st.lists(st.sampled_from(["aload", "astore", "getfin", "advance"]),
                    min_size=1, max_size=200),
       qlen=st.integers(2, 64))
@settings(max_examples=50, deadline=None)
def test_id_conservation_property(ops, qlen):
    """Property: no sequence of AMI ops leaks or duplicates request IDs."""
    eng = make_engine(queue_length=qlen)
    t = 0.0
    for op in ops:
        if op == "aload":
            eng.aload(0, 0)
        elif op == "astore":
            eng.astore(0, 8)
        elif op == "getfin":
            eng.getfin()
        else:
            t += 1500.0
            eng.advance(t)
        eng.check_invariants()
    eng.drain()
    while eng.getfin():
        pass
    eng.check_invariants()
    assert len(eng._free) + len(eng._free_cache) == qlen


# ------------------------------------------------------------ disambiguation
def test_cuckoo_conflict_serialization():
    d = CuckooAddressSet(slots_per_table=64)
    assert d.start_access(0x1000, "a")
    assert not d.start_access(0x1000, "b")        # same block conflicts
    assert not d.start_access(0x1008, "c")        # same 64B line
    assert d.start_access(0x2000, "d")            # different block fine
    assert d.end_access(0x1000) == "b"            # FIFO handoff
    assert d.end_access(0x1000) == "c"
    assert d.end_access(0x1008) is None           # last holder clears
    assert d.end_access(0x2000) is None
    assert d.active_count() == 0


@given(addrs=st.lists(st.integers(0, 1 << 20), min_size=1, max_size=300))
@settings(max_examples=50, deadline=None)
def test_cuckoo_acquire_release_property(addrs):
    """Acquire/release in LIFO batches never loses waiters or entries."""
    d = CuckooAddressSet(slots_per_table=16, num_tables=2)
    acquired = []
    waiting = 0
    for i, a in enumerate(addrs):
        if d.start_access(a, waiter=i):
            acquired.append(a)
        else:
            waiting += 1
    released_waiters = 0
    # release everything; ownership transfers drain the waiter queues
    while acquired:
        a = acquired.pop()
        w = d.end_access(a)
        if w is not None:
            released_waiters += 1
            acquired.append(a)        # waiter now owns the block
    assert released_waiters == waiting
    assert d.active_count() == 0


def test_cuckoo_overflow_spill():
    d = CuckooAddressSet(slots_per_table=2, num_tables=2, block_bytes=64)
    for i in range(64):
        assert d.start_access(i * 64)
    assert d.active_count() == 64          # spill keeps correctness
    for i in range(64):
        d.end_access(i * 64)
    assert d.active_count() == 0


# -------------------------------------------------------- scheduler behavior
def test_scheduler_nowait_and_await():
    eng = make_engine(queue_length=8)
    eng.mem[:16] = np.arange(16, dtype=np.uint8)
    got = {}

    def task():
        r1 = yield AloadNoWait(0, 0, 8)
        r2 = yield AloadNoWait(8, 8, 8)
        yield Cost(insts=10)
        yield AwaitRid(r1)
        yield AwaitRid(r2)
        a = yield SpmRead(0, 8)
        b = yield SpmRead(8, 8)
        got["a"], got["b"] = a, b

    Scheduler(eng).run([task()])
    assert bytes(got["a"]) == bytes(range(8))
    assert bytes(got["b"]) == bytes(range(8, 16))


def test_scheduler_id_exhaustion_parks_and_recovers():
    eng = make_engine(queue_length=2)

    def task(c):
        for i in range(4):
            yield Aload(c * 8, 8 * i, 8)
    s = Scheduler(eng)
    s.run([task(c) for c in range(4)])     # 4 tasks x 4 loads, 2 IDs
    eng.drain()
    eng.check_invariants()
    assert eng.stats["aload"] == 16
    assert eng.stats["alloc_fail"] > 0     # exhaustion happened and recovered


def test_mlp_scales_with_latency():
    """Fig 9's core claim: AMU MLP rises with latency (more overlap)."""
    def run(lat):
        far = FarMemoryModel(FarMemoryConfig.from_latency_us(lat))
        eng = AsyncMemoryEngine(EngineConfig(queue_length=256,
                                             granularity=8), far)
        def t(c):
            for i in range(8):
                yield Aload(c * 8, (c * 8 + i) % 1024 * 8, 8)
        s = Scheduler(eng)
        stats = s.run([t(c) for c in range(64)])
        return stats["mlp"]
    assert run(5.0) > run(0.5) > run(0.1) * 0.999


def test_cfg_registers_table1():
    """Table 1's cfgrr/cfgrw: granularity + queue_length reconfiguration."""
    eng = make_engine(queue_length=8, granularity=64)
    assert eng.cfgrr("granularity") == 64
    eng.cfgrw("granularity", 8)
    assert eng.cfgrr("granularity") == 8
    eng.cfgrw("queue_length", 128)
    assert eng.cfgrr("queue_length") == 128
    rids = [eng.aload(0, 0) for _ in range(128)]
    assert all(r > 0 for r in rids)
    assert eng.aload(0, 0) == 0          # 129th fails
    with pytest.raises(RuntimeError):
        eng.cfgrw("queue_length", 4)     # resize with requests in flight
    eng.drain()
    while eng.getfin():
        pass
    eng.cfgrw("queue_length", 4)
    eng.check_invariants()


@given(seed=st.integers(0, 10_000), ncoro=st.integers(1, 24),
       qlen=st.integers(4, 64))
@settings(max_examples=25, deadline=None)
def test_scheduler_random_gather_property(seed, ncoro, qlen):
    """Property: any mix of awaited / no-wait loads across many coroutines
    delivers exactly the right bytes to every SPM slot (IDs recycle, tokens
    don't cross wires)."""
    rng = np.random.default_rng(seed)
    eng = make_engine(queue_length=qlen, latency_us=float(rng.uniform(0.1, 5)))
    words = np.arange(256, dtype=np.uint64)
    eng.mem[:2048] = words.view(np.uint8)
    results = {}

    def task(c, n_ops):
        spm = c * 8
        got = []
        for i in range(n_ops):
            src = int(rng.integers(0, 256))
            if rng.random() < 0.5:
                yield Aload(spm, src * 8, 8)
            else:
                tok = yield AloadNoWait(spm, src * 8, 8)
                yield Cost(insts=int(rng.integers(1, 30)))
                yield AwaitRid(tok)
            data = yield SpmRead(spm, 8)
            got.append((src, np.frombuffer(data, np.uint64)[0]))
        results[c] = got

    s = Scheduler(eng)
    s.run([task(c, int(rng.integers(1, 12))) for c in range(ncoro)])
    eng.drain()
    eng.check_invariants()
    for c, got in results.items():
        for src, val in got:
            assert val == src, (c, src, val)
