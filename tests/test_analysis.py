"""Tests for the roofline HLO analyzer and the sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import hlo_analysis as ha
from repro.models import lm
from repro.runtime import sharding as shd


def test_while_trip_weighting():
    """A scan of 7 matmuls must count ~7x the flops of its body."""
    w = jnp.ones((64, 64), jnp.float32)

    def one(x):
        return x @ w

    def scanned(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.ones((8, 64))
    h1 = jax.jit(one).lower(x).compile().as_text()
    h7 = jax.jit(scanned).lower(x).compile().as_text()
    f1 = ha.analyze(h1).flops
    f7 = ha.analyze(h7).flops
    assert f1 > 0
    assert 6.0 < f7 / f1 < 8.5, (f1, f7)


def test_dot_flops_exact():
    a = jnp.ones((32, 128), jnp.float32)
    b = jnp.ones((128, 16), jnp.float32)
    hlo = jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text()
    counts = ha.analyze(hlo)
    assert counts.flops == pytest.approx(2 * 32 * 128 * 16, rel=0.01)


def test_shape_bytes_parsing():
    assert ha._shape_bytes("bf16[4,128]{1,0}") == 4 * 128 * 2
    assert ha._shape_bytes("(f32[8], s32[2,2])") == 8 * 4 + 4 * 4
    assert ha._shape_bytes("pred[]") == 1


# ---------------------------------------------------------- sharding rules
@pytest.fixture(scope="module")
def mesh8():
    if jax.device_count() < 8:
        pytest.skip("needs --xla_force_host_platform_device_count>=8 "
                    "(run via tests/test_system.py subprocess instead)")
    return jax.make_mesh((4, 2), ("data", "model"))


def test_param_specs_divisibility_safe():
    """Every generated spec must divide the leaf shape on a (16,16) mesh —
    checked structurally without building the mesh."""
    import numpy as np

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    mesh = FakeMesh()
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        shape = configs.TRAIN_4K
        par = configs.default_parallel(cfg, shape)
        params = jax.eval_shape(
            lambda c=cfg: lm.init_model(c, jax.random.PRNGKey(0)))
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        for path, leaf in flat:
            spec = shd.param_spec(cfg, par, mesh,
                                  jax.tree_util.keystr(path), leaf)
            for dim, entry in zip(leaf.shape, spec):
                if entry is None:
                    continue
                names = (entry,) if isinstance(entry, str) else entry
                size = int(np.prod([mesh.shape[n] for n in names]))
                assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_moe_expert_spec():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = configs.get_config("kimi-k2-1t-a32b")
    par = configs.default_parallel(cfg, configs.TRAIN_4K)
    leaf = jax.ShapeDtypeStruct((61, 384, 7168, 2048), jnp.float32)
    spec = shd.param_spec(cfg, par, FakeMesh(),
                          "['scan'][0]['ffn']['w_gate']", leaf)
    assert spec[1] == "model"          # experts over TP axis
