"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + no NaNs; prefill/decode consistency; M-RoPE/frontends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import blocks, lm

ARCHS = list(configs.ARCH_IDS)


def make_inputs(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    inputs = {"labels": jnp.array(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.frontend is not None and cfg.frontend.kind == "audio":
        inputs["features"] = jnp.array(
            rng.standard_normal((B, S, cfg.frontend.feature_dim)),
            jnp.float32)
    else:
        inputs["tokens"] = jnp.array(rng.integers(0, cfg.vocab_size, (B, S)))
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        inputs["vision_embeds"] = jnp.array(rng.standard_normal(
            (B, cfg.frontend.prefix_len, cfg.frontend.feature_dim)),
            jnp.float32)
    return inputs


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get_smoke_config(arch)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    inputs = make_inputs(cfg)
    loss, metrics = jax.jit(
        lambda p, i: lm.train_loss(cfg, p, i, remat="full"))(params, inputs)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    grads = jax.jit(jax.grad(lambda p, i: lm.train_loss(cfg, p, i)[0]))(
        params, inputs)
    gsq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda t: jnp.sum(jnp.square(t.astype(jnp.float32))),
                     grads))
    assert bool(jnp.isfinite(gsq)), f"{arch}: grad not finite"
    assert float(gsq) > 0.0, f"{arch}: zero gradients"


@pytest.mark.slow
@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get_config(a).is_decoder])
def test_prefill_decode_consistency(arch):
    """Decode step t must equal prefill of the t+1-long prefix (same model,
    cached vs uncached paths agree)."""
    cfg = configs.get_smoke_config(arch)
    params = lm.init_model(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    # capacity-MoE token dropping depends on batch composition, which breaks
    # cached-vs-uncached equivalence by design -> compare under dense routing
    moe_mode = "dense"
    inputs = make_inputs(cfg, B, S, seed=3)
    cache = lm.init_cache(cfg, B, S + 8)
    logits_p, cache = lm.prefill(cfg, params, inputs, cache,
                                 moe_mode=moe_mode)
    tok = jnp.argmax(logits_p[:, -1], -1)[:, None]
    logits_d, _ = lm.decode_step(cfg, params, tok, cache, moe_mode=moe_mode)

    # reference: prefill over the extended sequence
    ext = dict(inputs)
    ext["tokens"] = jnp.concatenate([inputs["tokens"], tok], axis=1)
    ext["labels"] = jnp.zeros_like(ext["tokens"])
    cache2 = lm.init_cache(cfg, B, S + 8)
    logits_ref, _ = lm.prefill(cfg, params, ext, cache2, moe_mode=moe_mode)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, -1], np.float32),
        np.asarray(logits_ref[:, -1], np.float32), atol=0.15, rtol=0.05)


def test_mrope_text_equals_rope():
    """For pure text (three equal position streams), M-RoPE == RoPE."""
    x = jnp.array(np.random.default_rng(0).standard_normal((2, 8, 4, 16)),
                  jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.stack([pos, pos, pos])
    a = blocks.apply_rope(x, pos, 10000.0)
    b = blocks.apply_rope(x, pos3, 10000.0, mrope_sections=(2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_chunked_attention_matches_naive():
    cfg = configs.get_smoke_config("qwen2-7b")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    inputs = make_inputs(cfg, B=1, S=64)
    old = dict(blocks.ATTN_CONFIG)
    try:
        blocks.ATTN_CONFIG.update(chunk_threshold=1 << 30)
        l_naive, _ = lm.train_loss(cfg, params, inputs, remat="none")
        blocks.ATTN_CONFIG.update(chunk_threshold=1, q_chunk=16, kv_chunk=16)
        l_chunk, _ = lm.train_loss(cfg, params, inputs, remat="none")
    finally:
        blocks.ATTN_CONFIG.update(old)
    assert abs(float(l_naive) - float(l_chunk)) < 2e-2


def test_moe_capacity_vs_dense_smoke():
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    inputs = make_inputs(cfg, B=2, S=16)
    l_dense, _ = lm.train_loss(cfg, params, inputs, moe_mode="dense",
                               remat="none")
    l_cap, _ = lm.train_loss(cfg, params, inputs, moe_mode="capacity",
                             remat="none")
    # capacity path may drop tokens but must be finite and close-ish
    assert bool(jnp.isfinite(l_dense)) and bool(jnp.isfinite(l_cap))
    assert abs(float(l_dense) - float(l_cap)) < 1.0


def test_param_counts_match_analytic():
    """init_model parameter totals track ModelConfig.param_count within 2%."""
    for arch in ("qwen2.5-3b", "granite-moe-1b-a400m", "rwkv6-7b"):
        cfg = configs.get_smoke_config(arch)
        params = lm.init_model(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        expect = cfg.param_count()
        assert abs(actual - expect) / expect < 0.05, (arch, actual, expect)
