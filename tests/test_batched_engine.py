"""Differential harness: `BatchedAsyncMemoryEngine` vs the scalar oracle.

The scalar `AsyncMemoryEngine` is the reference implementation; the batched
engine must be **trace-identical** to it — same request IDs, same done-times,
same SPM/far-memory bytes, same stats — both call-for-call (the same scalar
AMI sequence applied to both) and for the batch entry points
(`aload_batch`/`astore_batch`/`getfin_all`, which must be state-equivalent
to the scalar op sequence they replace). On top of that, the batch-stepped
`BatchScheduler` must run every workload port to a verified result and keep
the FIFO disambiguation hand-off.

`hypothesis` optional — tests/proplib.py falls back to seeded-random
example generation.
"""
import numpy as np
import pytest
from proplib import given, settings, st

from repro.configs.base import EngineConfig
from repro.core import simulator as sim
from repro.core.coroutines import (Acquire, Aload, BatchScheduler, Cost,
                                   Release, Scheduler)
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import (AsyncMemoryEngine, BatchedAsyncMemoryEngine,
                               SpmOverflow, make_engine)
from repro.core.farmem import FarMemoryConfig, FarMemoryModel, InstantMemory
from repro.core.workloads import WORKLOADS


def _far(kind: str, latency_us: float = 1.0):
    if kind == "instant":
        return InstantMemory()
    return FarMemoryModel(FarMemoryConfig.from_latency_us(latency_us))


def _pair(qlen=16, granularity=8, mem_kind="timed", latency_us=1.0,
          spm_bytes=64 * 1024, batch_ids=31):
    """A (scalar, batched) engine pair with identical config + far memory."""
    cfg = EngineConfig(queue_length=qlen, granularity=granularity,
                       spm_bytes=spm_bytes, batch_ids=batch_ids)
    engines = []
    for cls in (AsyncMemoryEngine, BatchedAsyncMemoryEngine):
        engines.append(cls(cfg, _far(mem_kind, latency_us),
                           record_trace=True))
    return engines


def _assert_identical(a: AsyncMemoryEngine, b: BatchedAsyncMemoryEngine):
    assert a.trace == b.trace
    assert a.stats == b.stats
    assert np.array_equal(a.spm, b.spm)
    assert np.array_equal(a.mem, b.mem)
    assert a.outstanding == b.outstanding
    assert a.finished_pending == b.finished_pending
    assert a.active_requests == b.active_requests


# =========================================================================
# Call-for-call equivalence: same scalar AMI sequence on both engines
# =========================================================================
@given(ops=st.lists(st.sampled_from(["aload", "astore", "getfin", "advance",
                                     "drainfin"]),
                    min_size=1, max_size=150),
       qlen=st.integers(2, 48), seed=st.integers(0, 1 << 20))
@settings(max_examples=40, deadline=None)
def test_scalar_ami_trace_identical(ops, qlen, seed):
    a, b = _pair(qlen=qlen)
    rng = np.random.default_rng(seed)
    for e in (a, b):
        e.mem[:4096] = np.arange(4096, dtype=np.uint8) ^ np.uint8(seed & 0xFF)
    t = 0.0
    for op in ops:
        spm = int(rng.integers(0, 64)) * 8
        addr = int(rng.integers(0, 500)) * 8
        for e in (a, b):
            if op == "aload":
                e.aload(spm, addr, 8)
            elif op == "astore":
                e.astore(spm, addr, 8)
            elif op == "getfin":
                e.getfin()
            elif op == "drainfin":
                e.getfin_all()
            else:
                e.advance(t + 900.0)
        if op == "advance":
            t += 900.0
        a.check_invariants()
        b.check_invariants()
    for e in (a, b):
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)


@pytest.mark.parametrize("mem_kind", ["instant", "timed"])
def test_interleaved_load_store_roundtrip(mem_kind):
    a, b = _pair(qlen=8, mem_kind=mem_kind)
    pattern = np.arange(256, dtype=np.uint8)
    for e in (a, b):
        e.mem[:256] = pattern
        for i in range(8):
            e.aload(i * 8, i * 8, 8)
        e.drain()
        e.getfin_all()
        e.spm_write(64, bytes(range(100, 116)))
        e.astore(64, 1024, 16)
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)
    assert bytes(a.mem[1024:1040]) == bytes(range(100, 116))


# =========================================================================
# Batch entry points == the scalar op sequence they replace
# =========================================================================
@given(rounds=st.integers(1, 12), qlen=st.integers(2, 40),
       seed=st.integers(0, 1 << 20))
@settings(max_examples=40, deadline=None)
def test_batch_ops_equal_scalar_sequence(rounds, qlen, seed):
    """aload_batch/astore_batch/getfin_all on the batched engine must be
    state- and stat-equivalent to the scalar loop on the oracle."""
    a, b = _pair(qlen=qlen)
    rng = np.random.default_rng(seed)
    fill = rng.integers(0, 256, 8192).astype(np.uint8)
    for e in (a, b):
        e.mem[:8192] = fill
    t = 0.0
    for _ in range(rounds):
        n = int(rng.integers(1, qlen + 4))        # may overshoot the ID pool
        spm = rng.integers(0, 64, n) * 8
        addr = rng.integers(0, 1000, n) * 8
        sizes = np.full(n, 8, np.int64)
        kind = rng.random() < 0.5
        if kind:
            rids_b = b.aload_batch(spm, addr, sizes)
            rids_a = np.array([a.aload(int(s), int(m), 8)
                               for s, m in zip(spm, addr)])
        else:
            rids_b = b.astore_batch(spm, addr, sizes)
            rids_a = np.array([a.astore(int(s), int(m), 8)
                               for s, m in zip(spm, addr)])
        assert np.array_equal(rids_a, rids_b)
        t += float(rng.uniform(0, 4000))
        a.advance(t)
        b.advance(t)
        fins_a = a.getfin_all()                   # scalar loop under the hood
        fins_b = b.getfin_all()                   # vectorized drain
        assert fins_a == fins_b
        a.check_invariants()
        b.check_invariants()
    for e in (a, b):
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)


def test_batch_alloc_failure_zero_padded():
    """IDs exhaust mid-batch: the tail comes back 0, exactly like the
    scalar loop, and the stats count each failed allocation."""
    a, b = _pair(qlen=4)
    rids_b = b.aload_batch(np.zeros(7, np.int64), np.arange(7) * 8,
                           np.full(7, 8))
    rids_a = np.array([a.aload(0, i * 8, 8) for i in range(7)])
    assert np.array_equal(rids_a, rids_b)
    assert (rids_b[:4] > 0).all() and (rids_b[4:] == 0).all()
    assert a.stats == b.stats
    assert b.stats["alloc_fail"] == 3


def test_batch_spm_overflow_raises():
    _, b = _pair(qlen=8)
    with pytest.raises(SpmOverflow):
        b.aload_batch(np.array([0, b.spm_data_bytes - 4]),
                      np.array([0, 0]), np.array([8, 8]))
    # failed batch must not leak IDs
    b.check_invariants()


@given(qlen=st.integers(2, 32), extra=st.integers(0, 8))
@settings(max_examples=25, deadline=None)
def test_id_conservation_under_batch_ops(qlen, extra):
    _, b = _pair(qlen=qlen)
    n = qlen + extra
    b.aload_batch(np.zeros(n, np.int64), np.arange(n) * 8, np.full(n, 8))
    b.check_invariants()
    b.drain()
    b.getfin_all()
    b.check_invariants()
    assert b.active_requests == 0


# =========================================================================
# Workload-level equivalence: every port, both memory models
# =========================================================================
@pytest.mark.parametrize("wl", list(WORKLOADS))
@pytest.mark.parametrize("mem_kind", ["instant", "timed"])
def test_workload_trace_identical(wl, mem_kind):
    """Running the same scheduler + workload against the scalar vs batched
    engine yields identical request traces, SPM and far-memory contents."""
    results = []
    for kind in ("scalar", "batched"):
        inst = WORKLOADS[wl].build(0)
        far = _far(mem_kind)
        eng = make_engine(kind, inst.engine_config, far, inst.mem,
                          record_trace=True)
        disamb = CuckooAddressSet() if inst.disambiguation else None
        sched = Scheduler(eng, disambiguator=disamb)
        if hasattr(inst, "make_round_tasks"):          # BFS
            frontier = [inst.root]
            while frontier:
                sched.run(inst.make_round_tasks(frontier))
                frontier = sorted(inst.next_frontier)
        else:
            sched.run(inst.tasks)
        eng.drain()
        eng.check_invariants()
        results.append((eng, inst, sched.t))
    (a, inst_a, t_a), (b, inst_b, t_b) = results
    assert a.trace == b.trace
    assert a.stats == b.stats
    assert np.array_equal(a.spm, b.spm)
    assert np.array_equal(a.mem, b.mem)
    assert t_a == t_b
    assert inst_a.verify(a.mem)
    assert inst_b.verify(b.mem)


def test_batch_scheduler_verified_end_to_end():
    """Spot-check the batch-stepped runtime loop end-to-end through
    `sim.run` (full coverage: tests/test_simulator.py runs every workload
    with engine="batched")."""
    out = sim.run("GUPS", "amu", 1.0, engine="batched")
    assert out["verified"]
    assert out["mlp"] > 5


# =========================================================================
# FIFO Acquire/Release ordering under the batch scheduler
# =========================================================================
@pytest.mark.parametrize("sched_cls", [Scheduler, BatchScheduler])
def test_acquire_release_fifo_order(sched_cls):
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(1.0))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=32, granularity=8), far)
    grant_order = []

    def task(i):
        yield Cost(insts=i)                       # stagger arrival slightly
        yield Acquire(0x1000)
        grant_order.append(i)
        yield Aload(0, 8 * i, 8)                  # hold across a far access
        yield Release(0x1000)

    sched = sched_cls(eng, disambiguator=CuckooAddressSet())
    sched.run([task(i) for i in range(12)])
    assert grant_order == sorted(grant_order), grant_order
    assert len(grant_order) == 12


@given(ncontend=st.integers(2, 16), seed=st.integers(0, 1 << 16))
@settings(max_examples=15, deadline=None)
def test_acquire_release_no_lost_waiters_batch(ncontend, seed):
    """Contending tasks on a shared block all complete under the batch
    scheduler; nobody is lost in the waiter hand-off."""
    rng = np.random.default_rng(seed)
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(
        float(rng.uniform(0.1, 3.0))))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=16, granularity=8), far)
    done = []

    def task(i, addr):
        yield Acquire(addr)
        yield Aload(0, 8 * (i % 64), 8)
        yield Release(addr)
        done.append(i)

    addrs = rng.integers(0, 3, ncontend) * 0x2000   # heavy contention
    sched = BatchScheduler(eng, disambiguator=CuckooAddressSet())
    sched.run([task(i, int(addrs[i])) for i in range(ncontend)])
    assert sorted(done) == list(range(ncontend))
