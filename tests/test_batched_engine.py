"""Differential harness: `BatchedAsyncMemoryEngine` vs the scalar oracle.

The scalar `AsyncMemoryEngine` is the reference implementation; the batched
engine must be **trace-identical** to it — same request IDs, same done-times,
same SPM/far-memory bytes, same stats — both call-for-call (the same scalar
AMI sequence applied to both) and for the batch entry points
(`aload_batch`/`astore_batch`/`getfin_all`, which must be state-equivalent
to the scalar op sequence they replace). On top of that, the batch-stepped
`BatchScheduler` must run every workload port to a verified result and keep
the FIFO disambiguation hand-off.

`hypothesis` optional — tests/proplib.py falls back to seeded-random
example generation.
"""
import numpy as np
import pytest
from proplib import given, settings, st

from repro.configs.base import EngineConfig
from repro.core import simulator as sim
from repro.core.coroutines import (Acquire, Aload, AloadVec, AstoreVec,
                                   AwaitRids, BatchScheduler, Cost, Release,
                                   Scheduler, SpmRead, SpmWrite)
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import (AsyncMemoryEngine, BatchedAsyncMemoryEngine,
                               SpmOverflow, make_engine)
from repro.core.farmem import (BimodalTail, FarMemoryConfig, FarMemoryModel,
                               InstantMemory, LognormalLatency, UniformJitter)

from repro.amu import REGISTRY


def _far(kind: str, latency_us: float = 1.0, max_inflight: int = 0):
    if kind == "instant":
        return InstantMemory()
    return FarMemoryModel(FarMemoryConfig.from_latency_us(
        latency_us, max_inflight=max_inflight))


def _pair(qlen=16, granularity=8, mem_kind="timed", latency_us=1.0,
          spm_bytes=64 * 1024, batch_ids=31, max_inflight=0):
    """A (scalar, batched) engine pair with identical config + far memory."""
    cfg = EngineConfig(queue_length=qlen, granularity=granularity,
                       spm_bytes=spm_bytes, batch_ids=batch_ids)
    engines = []
    for cls in (AsyncMemoryEngine, BatchedAsyncMemoryEngine):
        engines.append(cls(cfg, _far(mem_kind, latency_us, max_inflight),
                           record_trace=True))
    return engines


def _assert_identical(a: AsyncMemoryEngine, b: BatchedAsyncMemoryEngine):
    assert a.trace == b.trace
    assert a.stats == b.stats
    assert np.array_equal(a.spm, b.spm)
    assert np.array_equal(a.mem, b.mem)
    assert a.outstanding == b.outstanding
    assert a.finished_pending == b.finished_pending
    assert a.active_requests == b.active_requests


# =========================================================================
# Call-for-call equivalence: same scalar AMI sequence on both engines
# =========================================================================
@given(ops=st.lists(st.sampled_from(["aload", "astore", "getfin", "advance",
                                     "drainfin"]),
                    min_size=1, max_size=150),
       qlen=st.integers(2, 48), seed=st.integers(0, 1 << 20))
@settings(max_examples=40, deadline=None)
def test_scalar_ami_trace_identical(ops, qlen, seed):
    a, b = _pair(qlen=qlen)
    rng = np.random.default_rng(seed)
    for e in (a, b):
        e.mem[:4096] = np.arange(4096, dtype=np.uint8) ^ np.uint8(seed & 0xFF)
    t = 0.0
    for op in ops:
        spm = int(rng.integers(0, 64)) * 8
        addr = int(rng.integers(0, 500)) * 8
        for e in (a, b):
            if op == "aload":
                e.aload(spm, addr, 8)
            elif op == "astore":
                e.astore(spm, addr, 8)
            elif op == "getfin":
                e.getfin()
            elif op == "drainfin":
                e.getfin_all()
            else:
                e.advance(t + 900.0)
        if op == "advance":
            t += 900.0
        a.check_invariants()
        b.check_invariants()
    for e in (a, b):
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)


@pytest.mark.parametrize("mem_kind", ["instant", "timed"])
def test_interleaved_load_store_roundtrip(mem_kind):
    a, b = _pair(qlen=8, mem_kind=mem_kind)
    pattern = np.arange(256, dtype=np.uint8)
    for e in (a, b):
        e.mem[:256] = pattern
        for i in range(8):
            e.aload(i * 8, i * 8, 8)
        e.drain()
        e.getfin_all()
        e.spm_write(64, bytes(range(100, 116)))
        e.astore(64, 1024, 16)
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)
    assert bytes(a.mem[1024:1040]) == bytes(range(100, 116))


# =========================================================================
# Batch entry points == the scalar op sequence they replace
# =========================================================================
@given(rounds=st.integers(1, 12), qlen=st.integers(2, 40),
       seed=st.integers(0, 1 << 20))
@settings(max_examples=40, deadline=None)
def test_batch_ops_equal_scalar_sequence(rounds, qlen, seed):
    """aload_batch/astore_batch/getfin_all on the batched engine must be
    state- and stat-equivalent to the scalar loop on the oracle."""
    a, b = _pair(qlen=qlen)
    rng = np.random.default_rng(seed)
    fill = rng.integers(0, 256, 8192).astype(np.uint8)
    for e in (a, b):
        e.mem[:8192] = fill
    t = 0.0
    for _ in range(rounds):
        n = int(rng.integers(1, qlen + 4))        # may overshoot the ID pool
        spm = rng.integers(0, 64, n) * 8
        addr = rng.integers(0, 1000, n) * 8
        sizes = np.full(n, 8, np.int64)
        kind = rng.random() < 0.5
        if kind:
            rids_b = b.aload_batch(spm, addr, sizes)
            rids_a = np.array([a.aload(int(s), int(m), 8)
                               for s, m in zip(spm, addr)])
        else:
            rids_b = b.astore_batch(spm, addr, sizes)
            rids_a = np.array([a.astore(int(s), int(m), 8)
                               for s, m in zip(spm, addr)])
        assert np.array_equal(rids_a, rids_b)
        t += float(rng.uniform(0, 4000))
        a.advance(t)
        b.advance(t)
        fins_a = a.getfin_all()                   # scalar loop under the hood
        fins_b = b.getfin_all()                   # vectorized drain
        assert fins_a == fins_b
        a.check_invariants()
        b.check_invariants()
    for e in (a, b):
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)


def test_batch_alloc_failure_zero_padded():
    """IDs exhaust mid-batch: the tail comes back 0, exactly like the
    scalar loop, and the stats count each failed allocation."""
    a, b = _pair(qlen=4)
    rids_b = b.aload_batch(np.zeros(7, np.int64), np.arange(7) * 8,
                           np.full(7, 8))
    rids_a = np.array([a.aload(0, i * 8, 8) for i in range(7)])
    assert np.array_equal(rids_a, rids_b)
    assert (rids_b[:4] > 0).all() and (rids_b[4:] == 0).all()
    assert a.stats == b.stats
    assert b.stats["alloc_fail"] == 3


def test_batch_spm_overflow_raises():
    _, b = _pair(qlen=8)
    with pytest.raises(SpmOverflow):
        b.aload_batch(np.array([0, b.spm_data_bytes - 4]),
                      np.array([0, 0]), np.array([8, 8]))
    with pytest.raises(SpmOverflow):
        b.aload_batch(np.array([-8, 16]), np.array([0, 8]),
                      np.array([8, 8]))     # negative addr must not wrap
    # failed batch must not leak IDs
    b.check_invariants()


@given(qlen=st.integers(2, 32), extra=st.integers(0, 8))
@settings(max_examples=25, deadline=None)
def test_id_conservation_under_batch_ops(qlen, extra):
    _, b = _pair(qlen=qlen)
    n = qlen + extra
    b.aload_batch(np.zeros(n, np.int64), np.arange(n) * 8, np.full(n, 8))
    b.check_invariants()
    b.drain()
    b.getfin_all()
    b.check_invariants()
    assert b.active_requests == 0


# =========================================================================
# Workload-level equivalence: every port, both memory models
# =========================================================================
@pytest.mark.parametrize("wl", REGISTRY.names())
@pytest.mark.parametrize("mem_kind", ["instant", "timed"])
def test_workload_trace_identical(wl, mem_kind):
    """Running the same scheduler + workload against the scalar vs batched
    engine yields identical request traces, SPM and far-memory contents."""
    results = []
    for kind in ("scalar", "batched"):
        inst = REGISTRY[wl].build(0)
        far = _far(mem_kind)
        eng = make_engine(kind, inst.engine_config, far, inst.mem,
                          record_trace=True)
        disamb = CuckooAddressSet() if inst.disambiguation else None
        sched = Scheduler(eng, disambiguator=disamb)
        if hasattr(inst, "make_round_tasks"):          # BFS
            frontier = [inst.root]
            while frontier:
                sched.run(inst.make_round_tasks(frontier))
                frontier = sorted(inst.next_frontier)
        else:
            sched.run(inst.tasks)
        eng.drain()
        eng.check_invariants()
        results.append((eng, inst, sched.t))
    (a, inst_a, t_a), (b, inst_b, t_b) = results
    assert a.trace == b.trace
    assert a.stats == b.stats
    assert np.array_equal(a.spm, b.spm)
    assert np.array_equal(a.mem, b.mem)
    assert t_a == t_b
    assert inst_a.verify(a.mem)
    assert inst_b.verify(b.mem)


def test_batch_scheduler_verified_end_to_end():
    """Spot-check the batch-stepped runtime loop end-to-end through
    `sim.run` (full coverage: tests/test_simulator.py runs every workload
    with engine="batched")."""
    out = sim.run("GUPS", "amu", 1.0, engine="batched")
    assert out["verified"]
    assert out["mlp"] > 5


# =========================================================================
# FIFO Acquire/Release ordering under the batch scheduler
# =========================================================================
@pytest.mark.parametrize("sched_cls", [Scheduler, BatchScheduler])
def test_acquire_release_fifo_order(sched_cls):
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(1.0))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=32, granularity=8), far)
    grant_order = []

    def task(i):
        yield Cost(insts=i)                       # stagger arrival slightly
        yield Acquire(0x1000)
        grant_order.append(i)
        yield Aload(0, 8 * i, 8)                  # hold across a far access
        yield Release(0x1000)

    sched = sched_cls(eng, disambiguator=CuckooAddressSet())
    sched.run([task(i) for i in range(12)])
    assert grant_order == sorted(grant_order), grant_order
    assert len(grant_order) == 12


# =========================================================================
# issue_batch under max_inflight: vectorized backpressure must be
# time-identical to the scalar issue() loop (regression for the silent
# scalar fallback that made MSHR-limited sweeps slow)
# =========================================================================
@given(n=st.integers(1, 120), max_inflight=st.integers(1, 24),
       jitter=st.sampled_from([0.0, 0.2]), seed=st.integers(0, 1 << 16))
@settings(max_examples=40, deadline=None)
def test_issue_batch_max_inflight_time_identical(n, max_inflight, jitter,
                                                 seed):
    rng = np.random.default_rng(seed)
    sizes = rng.choice([8, 64, 512], size=n)
    cfg = dict(base_latency_cycles=3000.0, bandwidth_bytes_per_cycle=21.3,
               max_inflight=max_inflight, jitter_frac=jitter, seed=seed)
    a = FarMemoryModel(FarMemoryConfig(**cfg))
    b = FarMemoryModel(FarMemoryConfig(**cfg))
    now = float(rng.uniform(0, 5000))
    dones_a = np.array([a.issue(now, int(s)) for s in sizes])
    dones_b = b.issue_batch(now, sizes)
    assert np.array_equal(dones_a, dones_b)
    assert a._link_free == b._link_free
    assert sorted(a._inflight) == sorted(b._inflight)
    assert a.requests == b.requests and a.bytes_moved == b.bytes_moved
    t_end = float(dones_a.max()) + 1.0
    assert a.avg_mlp(t_end) == b.avg_mlp(t_end)
    assert a.inflight_at(now + 1.0) == b.inflight_at(now + 1.0)


# =========================================================================
# Latency-distribution determinism: every distribution draws through a
# seeded RNG whose array fills consume the bitstream exactly like
# sequential scalar draws, so scalar and batch paths stay bit-identical
# =========================================================================
_DISTS = {
    "uniform": UniformJitter(0.2),
    "lognormal": LognormalLatency(0.7),
    "bimodal": BimodalTail(0.1, 16.0),
}


@pytest.mark.parametrize("dist", list(_DISTS.values()), ids=list(_DISTS))
@given(n=st.integers(1, 80), max_inflight=st.sampled_from([0, 1, 6]),
       seed=st.integers(0, 1 << 16))
@settings(max_examples=20, deadline=None)
def test_issue_batch_distribution_bitstream_identical(dist, n, max_inflight,
                                                      seed):
    """Scalar-vs-batch RNG bitstream identity for each latency
    distribution, on both the unlimited and backpressured paths."""
    rng = np.random.default_rng(seed)
    sizes = rng.choice([8, 64, 512], size=n)
    cfg = dict(base_latency_cycles=3000.0, bandwidth_bytes_per_cycle=21.3,
               max_inflight=max_inflight, distribution=dist, seed=seed)
    a = FarMemoryModel(FarMemoryConfig(**cfg))
    b = FarMemoryModel(FarMemoryConfig(**cfg))
    now = float(rng.uniform(0, 5000))
    dones_a = np.array([a.issue(now, int(s)) for s in sizes])
    dones_b = b.issue_batch(now, sizes)
    assert np.array_equal(dones_a, dones_b)
    assert a._link_free == b._link_free
    assert a._token == b._token          # aligned on BOTH paths (S1 fix)
    assert sorted(a._inflight) == sorted(b._inflight)


def test_uniform_jitter_matches_legacy_jitter_frac():
    """UniformJitter(f) is the typed spelling of jitter_frac=f: identical
    draws for the same seed, scalar and batch."""
    legacy = FarMemoryModel(FarMemoryConfig(jitter_frac=0.3, seed=9))
    typed = FarMemoryModel(FarMemoryConfig(distribution=UniformJitter(0.3),
                                           seed=9))
    sizes = np.full(32, 64)
    assert np.array_equal(
        np.array([legacy.issue(0.0, 64) for _ in range(32)]),
        np.array([typed.issue(0.0, 64) for _ in range(32)]))
    legacy2 = FarMemoryModel(FarMemoryConfig(jitter_frac=0.3, seed=9))
    typed2 = FarMemoryModel(FarMemoryConfig(distribution=UniformJitter(0.3),
                                            seed=9))
    assert np.array_equal(legacy2.issue_batch(0.0, sizes),
                          typed2.issue_batch(0.0, sizes))


def test_distribution_shapes():
    """Qualitative shape checks: lognormal is mean-preserving with a right
    tail; bimodal's p50 is the base latency and its p99 the tail mult."""
    rng = np.random.default_rng(0)
    ln = LognormalLatency(0.7).draw(rng, 200_000)
    assert np.mean(ln) == pytest.approx(1.0, rel=0.02)
    assert np.quantile(ln, 0.99) > 3 * np.quantile(ln, 0.5)
    bi = BimodalTail(0.05, 16.0).draw(rng, 200_000)
    assert np.quantile(bi, 0.5) == 1.0
    assert np.quantile(bi, 0.99) == 16.0
    assert np.mean(bi) == pytest.approx(1.0 + 0.05 * 15.0, rel=0.05)


def test_issue_batch_max_inflight_across_calls():
    """Backpressure state (heap + link) must carry correctly across a mix of
    scalar and batch issues at advancing timestamps."""
    cfg = dict(base_latency_cycles=1000.0, bandwidth_bytes_per_cycle=8.0,
               max_inflight=4)
    a = FarMemoryModel(FarMemoryConfig(**cfg))
    b = FarMemoryModel(FarMemoryConfig(**cfg))
    rng = np.random.default_rng(3)
    now = 0.0
    for _ in range(12):
        n = int(rng.integers(1, 9))
        sizes = rng.choice([8, 64], size=n)
        da = np.array([a.issue(now, int(s)) for s in sizes])
        db = b.issue_batch(now, sizes)
        assert np.array_equal(da, db)
        now += float(rng.uniform(0, 3000))
    assert a._link_free == b._link_free
    assert sorted(a._inflight) == sorted(b._inflight)


def test_max_inflight_engine_trace_identical():
    """End-to-end: the batched engine's batch entry points under an
    MSHR-limited far memory are trace-identical (incl. done-times) to the
    scalar oracle — the old fallback is gone, the new path must not diverge."""
    a, b = _pair(qlen=24, max_inflight=6)
    rng = np.random.default_rng(11)
    for e in (a, b):
        e.mem[:4096] = np.arange(4096, dtype=np.uint8)
    t = 0.0
    for _ in range(10):
        n = int(rng.integers(1, 20))
        spm = rng.integers(0, 64, n) * 8
        addr = rng.integers(0, 500, n) * 8
        if rng.random() < 0.5:
            rb = b.aload_batch(spm, addr, np.full(n, 8))
            ra = np.array([a.aload(int(s), int(m), 8)
                           for s, m in zip(spm, addr)])
        else:
            rb = b.astore_batch(spm, addr, np.full(n, 8))
            ra = np.array([a.astore(int(s), int(m), 8)
                           for s, m in zip(spm, addr)])
        assert np.array_equal(ra, rb)
        t += float(rng.uniform(0, 4000))
        a.advance(t)
        b.advance(t)
        assert a.getfin_all() == b.getfin_all()
    for e in (a, b):
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)


# =========================================================================
# _move_data fast paths: contiguous / word-gather / mixed-granularity
# =========================================================================
@given(seed=st.integers(0, 1 << 16), qlen=st.integers(4, 32),
       mixed=st.booleans())
@settings(max_examples=30, deadline=None)
def test_move_data_granularity_paths(seed, qlen, mixed):
    """Same-granularity retirement (word-gather + 2D fancy) and the
    mixed-granularity fallback all match the scalar oracle byte-for-byte,
    including duplicate destinations (last-writer-wins)."""
    a, b = _pair(qlen=qlen)
    rng = np.random.default_rng(seed)
    fill = rng.integers(0, 256, 8192).astype(np.uint8)
    for e in (a, b):
        e.mem[:8192] = fill
    t = 0.0
    for _ in range(6):
        n = int(rng.integers(1, qlen + 1))
        if mixed:
            sizes = rng.choice([8, 16, 24], size=n)
            spm = rng.integers(0, 64, n) * 8
            addr = rng.integers(0, 500, n) * 8
        else:
            sizes = np.full(n, 8)
            # odd (unaligned) addresses push the same-size path off the
            # word-gather tier onto the 2D fancy tier
            spm = rng.integers(0, 400, n) + (0 if rng.random() < 0.5 else 1)
            addr = rng.integers(0, 4000, n)
        for e in (a, b):
            for i in range(n):
                e.aload(int(spm[i]), int(addr[i]), int(sizes[i]))
        t += float(rng.uniform(500, 5000))
        for e in (a, b):
            e.advance(t)
            e.getfin_all()
    for e in (a, b):
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)


def test_move_data_contiguous_block_path():
    """Ascending same-granularity runs retire via the single-slice copy."""
    a, b = _pair(qlen=32, granularity=64)
    pattern = np.arange(4096, dtype=np.int64).view(np.uint8)
    for e in (a, b):
        e.mem[:pattern.size] = pattern
        for i in range(16):
            e.aload(i * 64, i * 64, 64)          # contiguous both sides
        e.drain()
        e.getfin_all()
        e.spm_write(0, bytes(range(64)))
        for i in range(16):                       # contiguous store run
            e.astore(0, 8192 + i * 64, 64)
        e.drain()
        e.getfin_all()
    _assert_identical(a, b)
    assert bytes(a.spm[:64]) == bytes(range(64))


# =========================================================================
# Vector commands: AloadVec/AstoreVec/AwaitRids
# =========================================================================
def _run_port(wl: str, vector: bool, mem_kind: str, engine="batched",
              sched_cls=BatchScheduler, max_inflight=0, **build_kw):
    """Run a workload port to completion; returns (engine, instance)."""
    kw = {"vector": True, **build_kw} if vector else dict(build_kw)
    if wl in ("GUPS", "Redis"):
        kw["distinct"] = True          # conflict-free -> deterministic bytes
    inst = REGISTRY[wl].build(0, **kw)
    far = _far(mem_kind, max_inflight=max_inflight)
    eng = make_engine(engine, inst.engine_config, far, inst.mem)
    disamb = CuckooAddressSet() if inst.disambiguation else None
    sched = sched_cls(eng, disambiguator=disamb)
    if hasattr(inst, "make_round_tasks"):          # BFS: level-synchronous
        frontier = [inst.root]
        while frontier:
            sched.run(inst.make_round_tasks(frontier))
            frontier = sorted(inst.next_frontier)
    else:
        sched.run(inst.tasks)
    eng.drain()
    eng.getfin_all()
    eng.check_invariants()
    return eng, inst


_scalar_port_cache = {}


def _scalar_port_mem(wl: str, mem_kind: str):
    key = (wl, mem_kind)
    if key not in _scalar_port_cache:
        eng, inst = _run_port(wl, vector=False, mem_kind=mem_kind)
        assert inst.verify(eng.mem)
        _scalar_port_cache[key] = eng.mem.copy()
    return _scalar_port_cache[key]


@pytest.mark.parametrize("wl", sorted(REGISTRY.vector_names()))
@pytest.mark.parametrize("mem_kind", ["instant", "timed"])
def test_vector_port_matches_scalar_port(wl, mem_kind):
    """Every vector port must be trace-equivalent to its scalar port: same
    far-memory bytes, verify() passes (found/hist side-results included).
    BFS parent claims race across tasks by design (any valid BFS tree
    passes), so its final bytes are schedule- but not port-pinned: the
    vector port must produce a verified tree, not identical bytes."""
    eng, inst = _run_port(wl, vector=True, mem_kind=mem_kind)
    assert inst.verify(eng.mem)
    if wl != "BFS":
        ref_mem = _scalar_port_mem(wl, mem_kind)
        assert np.array_equal(eng.mem, ref_mem)


@pytest.mark.parametrize("wl", ["GUPS", "STREAM"])
def test_vector_port_matches_scalar_port_max_inflight(wl):
    """Vector ports under an MSHR-limited (max_inflight) far memory — the
    configuration the old issue_batch fallback served scalar-only."""
    eng_s, inst_s = _run_port(wl, vector=False, mem_kind="timed",
                              max_inflight=16)
    eng_v, inst_v = _run_port(wl, vector=True, mem_kind="timed",
                              max_inflight=16)
    assert inst_s.verify(eng_s.mem)
    assert inst_v.verify(eng_v.mem)
    assert np.array_equal(eng_v.mem, eng_s.mem)


@pytest.mark.parametrize("sched_cls", [Scheduler, BatchScheduler])
def test_vector_commands_on_scalar_engine(sched_cls):
    """Vector commands work against the scalar oracle too (base-class
    scalar-issue fallback), under both runtime loops."""
    ref_mem = _scalar_port_mem("GUPS", "instant")
    eng, inst = _run_port("GUPS", vector=True, mem_kind="instant",
                          engine="scalar", sched_cls=sched_cls)
    assert inst.verify(eng.mem)
    assert np.array_equal(eng.mem, ref_mem)


@pytest.mark.parametrize("sched_cls", [Scheduler, BatchScheduler])
def test_vector_partial_allocation_parks_and_recovers(sched_cls):
    """A vector bigger than the whole ID pool parks its remainder and the
    task resumes exactly once, after every element has been issued."""
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(1.0))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=4, granularity=8), far)
    eng.mem[:256] = np.arange(256, dtype=np.uint8)
    got = {}

    def task():
        slots = np.arange(16) * 8
        rids = yield AloadVec(slots, slots, 8)
        assert len(rids) == 16
        yield AwaitRids(rids)
        got["data"] = yield SpmRead(0, 128)

    sched_cls(eng).run([task()])
    eng.drain()
    eng.getfin_all()
    eng.check_invariants()
    assert bytes(got["data"]) == bytes(range(128))
    assert eng.stats["alloc_fail"] > 0


def test_await_rids_after_completion():
    """AwaitRids over tokens that already completed (unclaimed) resumes
    immediately; mixed claimed/unclaimed resolves exactly once."""
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=16, granularity=8), InstantMemory())
    eng.mem[:128] = np.arange(128, dtype=np.uint8)
    got = {}

    def task():
        rids = yield AloadVec(np.arange(8) * 8, np.arange(8) * 8, 8)
        yield Cost(insts=500)            # completions land before the await
        yield AwaitRids(rids)
        got["data"] = yield SpmRead(0, 64)

    BatchScheduler(eng).run([task()])
    assert bytes(got["data"]) == bytes(range(64))


def test_astore_vec_roundtrip():
    """AstoreVec payloads are captured at issue and land at the right
    far-memory addresses (scatter, duplicate-free)."""
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=16, granularity=8), InstantMemory())

    def task():
        yield SpmWrite(0, bytes(range(64)))
        rids = yield AstoreVec(np.arange(8) * 8, 1024 + np.arange(8)[::-1] * 8, 8)
        yield AwaitRids(rids)

    BatchScheduler(eng).run([task()])
    eng.drain()
    eng.getfin_all()
    for i in range(8):
        expect = bytes(range(i * 8, i * 8 + 8))
        assert bytes(eng.mem[1024 + (7 - i) * 8:1024 + (7 - i) * 8 + 8]) == expect


@given(ncontend=st.integers(2, 16), seed=st.integers(0, 1 << 16))
@settings(max_examples=15, deadline=None)
def test_acquire_release_no_lost_waiters_batch(ncontend, seed):
    """Contending tasks on a shared block all complete under the batch
    scheduler; nobody is lost in the waiter hand-off."""
    rng = np.random.default_rng(seed)
    far = FarMemoryModel(FarMemoryConfig.from_latency_us(
        float(rng.uniform(0.1, 3.0))))
    eng = BatchedAsyncMemoryEngine(
        EngineConfig(queue_length=16, granularity=8), far)
    done = []

    def task(i, addr):
        yield Acquire(addr)
        yield Aload(0, 8 * (i % 64), 8)
        yield Release(addr)
        done.append(i)

    addrs = rng.integers(0, 3, ncontend) * 0x2000   # heavy contention
    sched = BatchScheduler(eng, disambiguator=CuckooAddressSet())
    sched.run([task(i, int(addrs[i])) for i in range(ncontend)])
    assert sorted(done) == list(range(ncontend))
