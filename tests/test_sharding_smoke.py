"""In-process jit+sharding smoke (tier-1).

The slow system tests (tests/test_system.py, nightly) drive sharded
train/serve/resume end-to-end in subprocesses — minutes of wall clock. This
smoke exercises the SAME code path in-process and in seconds: a real
``jax.jit`` with in/out shardings and donation on the 4x2 ("data", "model")
debug mesh (8 fake CPU devices, forced by tests/conftest.py before jax
initializes), through ``params_shardings`` / ``opt_state_shardings`` /
``make_train_step`` on a smoke-sized config. A regression in the sharding
rules, the step builder, or mesh plumbing fails here on every push instead
of at the next nightly.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.launch.mesh import make_debug_mesh
from repro.models import lm
from repro.optim import adamw
from repro.runtime import hints
from repro.runtime import sharding as shd
from repro.runtime import steps as steps_mod


def test_jit_sharding_smoke():
    if jax.device_count() < 8:
        pytest.skip("needs 8 (fake CPU) devices; conftest.py sets XLA_FLAGS "
                    "before jax init — something initialized jax earlier")
    cfg = configs.get_smoke_config("qwen2.5-3b")
    shape = configs.ShapeConfig("smoke", 16, 8, "train")
    par = configs.ParallelConfig(remat="full")
    mesh = make_debug_mesh(8)
    hints.set_mesh_axes({k: v for k, v in mesh.shape.items()})
    opt_cfg = adamw.AdamWConfig(total_steps=2)
    with mesh:
        params = lm.init_model(cfg, jax.random.PRNGKey(0))
        p_sh = shd.params_shardings(cfg, par, mesh, params)
        o_sh = shd.opt_state_shardings(cfg, par, mesh, params)
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(adamw.init_state(params), o_sh)
        step = jax.jit(steps_mod.make_train_step(cfg, par, opt_cfg),
                       out_shardings=(p_sh, o_sh, None),
                       donate_argnums=(0, 1))
        losses = []
        for i in range(2):
            batch = {k: jnp.asarray(v)
                     for k, v in synthetic_batch(cfg, shape, i).items()}
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
    assert all(l == l for l in losses), losses        # no NaN
    assert losses[-1] < losses[0] + 0.5, losses       # not diverging
    # the state is actually laid out across the mesh, not replicated on one
    # device: at least one param leaf spans multiple devices
    spans = {len(leaf.sharding.device_set)
             for leaf in jax.tree_util.tree_leaves(params)}
    assert max(spans) > 1, spans
