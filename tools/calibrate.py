"""Calibration harness: our model vs the paper's Table 4 / headline targets."""
import sys
sys.path.insert(0, "src")
from repro.core import simulator as sim

LATS = [0.1, 0.2, 0.5, 1.0, 2.0, 5.0]
T4 = {
    "GUPS":   {"base": [1.00, 1.38, 2.54, 4.40, 8.21, 19.83],
               "amu":  [0.96, 0.96, 0.97, 0.98, 1.00, 1.03]},
    "HJ":     {"base": [1.00, 1.41, 2.61, 4.59, 8.61, 20.70],
               "amu":  [2.69, 2.67, 2.68, 2.71, 2.79, 3.08]},
    "STREAM": {"base": [1.00, 1.28, 2.28, 4.00, 7.63, 18.66],
               "amu":  [1.64, 1.67, 1.74, 1.87, 2.18, 3.33]},
}

def norm_curves(wl):
    base = [sim.run(wl, "baseline", L)["us"] for L in LATS]
    amu = [sim.run(wl, "amu", L, verify=False)["us"] for L in LATS]
    b0 = base[0]
    return [b/b0 for b in base], [a/b0 for a in amu]

def main(workloads):
    for wl in workloads:
        b, a = norm_curves(wl)
        print(f"== {wl}")
        print("  base ours :", " ".join(f"{x:7.2f}" for x in b))
        if wl in T4: print("  base paper:", " ".join(f"{x:7.2f}" for x in T4[wl]["base"]))
        print("  amu  ours :", " ".join(f"{x:7.2f}" for x in a))
        if wl in T4: print("  amu  paper:", " ".join(f"{x:7.2f}" for x in T4[wl]["amu"]))

if __name__ == "__main__":
    main(sys.argv[1:] or ["GUPS", "HJ", "STREAM"])
