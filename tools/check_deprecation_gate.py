"""CI deprecation gate: no in-repo caller may touch the shimmed
pre-AmuSession surface (`run_amu`, the WORKLOADS/VECTOR_WORKLOADS dicts).

Installs an error filter for AmuDeprecationWarning, then imports every
driver module and exercises the benchmark/sim entry paths — any shim use at
import time or in the exercised paths raises. (An interpreter-level
``-W error::repro.amu...`` cannot express this: resolving the dotted
category at startup imports numpy before the interpreter is ready for it.
The test suite enforces the same filter via tests/conftest.py.)

Usage: PYTHONPATH=src python tools/check_deprecation_gate.py
"""
import os
import sys
import warnings

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

from repro.amu.deprecation import AmuDeprecationWarning  # noqa: E402

warnings.simplefilter("error", AmuDeprecationWarning)

import benchmarks.kernel_micro            # noqa: E402,F401
import benchmarks.paper_figures as pf     # noqa: E402
import benchmarks.roofline                # noqa: E402,F401
import benchmarks.run                     # noqa: E402,F401
import examples.amu_workload              # noqa: E402,F401
import repro.core.simulator as sim        # noqa: E402
import repro.core.workloads               # noqa: E402,F401
import tools.calibrate                    # noqa: E402,F401

# exercise the figure-driver AMU path end to end (shim-free by construction)
out = pf._run("GUPS", "amu", 0.5, verify=True)
assert out["verified"], out
out = sim.run("GUPS", "baseline", 0.5)
assert out["cycles"] > 0

print("deprecation gate: all drivers clean of the shimmed surface")
