#!/usr/bin/env python3
"""amilint CLI — static AMI protocol lint for port generators.

Usage::

    python tools/amilint.py --registry            # all @workload ports
    python tools/amilint.py examples/amu_workload.py src/repro/core/*.py
    python tools/amilint.py --registry --json examples/amu_workload.py

Exit status is 1 when any finding survives suppression, 0 otherwise.
Suppress a false positive on its line with ``# amilint: ignore`` or
``# amilint: ignore[AMI002]``.
"""
from __future__ import annotations

import argparse
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.amilint import lint_file, lint_registry, render  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="amilint", description=__doc__)
    ap.add_argument("files", nargs="*", help="Python files to lint")
    ap.add_argument("--registry", action="store_true",
                    help="also lint the source of every registered "
                         "@workload builder")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    args = ap.parse_args(argv)
    if not args.files and not args.registry:
        ap.error("nothing to lint: pass files and/or --registry")

    findings = []
    if args.registry:
        findings.extend(lint_registry())
    linted = {f.file for f in findings}
    for path in args.files:
        if path not in linted:
            findings.extend(lint_file(path))
    print(render(findings, as_json=args.as_json))
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
