"""Serving example, two layers of the same mechanism:

Default: the paged-KV serving workload through the AMU session API —
open-loop request arrivals gather their KV pages from tiered far memory
(local / CXL / cross-switch) with one AMI vector gather per request, and
per-request completion-latency percentiles come back on `RunStats`. The
synchronous page-fault baseline runs first for the tail-latency contrast.

`--lm` instead runs a real transformer decode: batched requests through
prefill + paged decode, with the decode attention optionally running the
paged_attention Pallas kernel (`--use-kernels`) — KV pages streamed
through VMEM are the kernel twin of the far-memory gathers above.

Usage: PYTHONPATH=src python examples/serve_paged.py [--requests N]
       PYTHONPATH=src python examples/serve_paged.py --lm [--use-kernels]
"""
import argparse
import time


def serve_sim(requests: int) -> None:
    from repro.amu import AmuConfig, AmuSession
    from repro.core.serving import serve_regions

    base = AmuConfig(far=serve_regions(requests=requests))
    print(f"=== paged-KV serving, {requests} open-loop requests ===")
    print(f"{'data plane':>12s} {'p50':>8s} {'p99':>8s} {'p999':>8s} "
          f"{'MLP':>6s}")
    sync_mean = None
    for label, kw in (("page-fault", dict(data_plane="sync")),
                      ("ami", {}),
                      ("ami-vector", {})):
        cfg = base.derive(vector=(label == "ami-vector"))
        with AmuSession(cfg) as s:
            out = s.run("paged_kv_serve", requests=requests,
                        coroutines=16, **kw)
        assert out.verified
        sync_mean = sync_mean or out.req_mean_us
        print(f"{label:>12s} {out.req_p50_us:7.1f}u {out.req_p99_us:7.1f}u "
              f"{out.req_p999_us:7.1f}u {out.mlp:6.2f}"
              + (f"  ({sync_mean / out.req_mean_us:.1f}x mean vs page-fault)"
                 if label != "page-fault" else ""))
    print("\nMLP across concurrent requests is the whole mechanism: the "
          "AMI planes\noverlap every tenant's page gathers where the "
          "page-fault plane blocks.")


def serve_lm(args) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.models import lm

    cfg = configs.get_smoke_config(args.arch)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.max_new
    rng = np.random.default_rng(0)

    prefill = jax.jit(lambda p, b, c: lm.prefill(
        cfg, p, b, c, use_kernels=args.use_kernels))
    decode = jax.jit(lambda p, t, c: lm.decode_step(
        cfg, p, t, c, use_kernels=args.use_kernels))

    def serve_wave(wave: int) -> float:
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)))
        cache = lm.init_cache(cfg, args.batch, max_len)
        logits, cache = prefill(params, {"tokens": prompts}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
        rate = args.batch * (args.max_new - 1) / dt
        print(f"wave {wave}: {rate:8.1f} tok/s "
              f"(paged kernel: {args.use_kernels})")
        return rate

    rates = [serve_wave(w) for w in range(2)]
    print(f"mean decode throughput: {sum(rates) / len(rates):.1f} tok/s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--lm", action="store_true",
                    help="run the transformer prefill+decode demo instead")
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--use-kernels", action="store_true")
    args = ap.parse_args()
    if args.lm:
        serve_lm(args)
    else:
        serve_sim(args.requests)


if __name__ == "__main__":
    main()
