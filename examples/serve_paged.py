"""Serving example: batched requests through prefill + paged decode, with
the decode attention optionally running the paged_attention Pallas kernel —
the AMU serving path (KV pages are 'far memory' streamed through VMEM).

Also demonstrates continuous batching at the example level: two request
waves share the cache arrays; finished rows are recycled.

Usage: PYTHONPATH=src python examples/serve_paged.py [--use-kernels]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--use-kernels", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.max_new
    rng = np.random.default_rng(0)

    prefill = jax.jit(lambda p, b, c: lm.prefill(
        cfg, p, b, c, use_kernels=args.use_kernels))
    decode = jax.jit(lambda p, t, c: lm.decode_step(
        cfg, p, t, c, use_kernels=args.use_kernels))

    def serve_wave(wave: int) -> float:
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)))
        cache = lm.init_cache(cfg, args.batch, max_len)
        logits, cache = prefill(params, {"tokens": prompts}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
        rate = args.batch * (args.max_new - 1) / dt
        print(f"wave {wave}: {rate:8.1f} tok/s "
              f"(paged kernel: {args.use_kernels})")
        return rate

    rates = [serve_wave(w) for w in range(2)]
    print(f"mean decode throughput: {np.mean(rates):.1f} tok/s")


if __name__ == "__main__":
    main()
