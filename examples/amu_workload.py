"""Add a new AMU workload in <50 lines: the `@workload` + `ctx` + session
pattern end to end.

The workload below ("DOTV") computes a dot product over two far-memory
vectors: each coroutine vector-loads a chunk of both operands per generator
hop (`ctx.aload_vec`), reduces through zero-copy `ctx.spm_read` views,
publishes its partial far-side with `ctx.astore`, and the builder's
`verify()` pins the stored partials against numpy. Everything between the
two `# --- workload ---` markers is the complete scenario definition — 41
lines — after which every engine/scheduler/latency configuration comes free
via `AmuConfig`.

Usage: PYTHONPATH=src python examples/amu_workload.py
"""
import numpy as np

from repro.amu import AmuConfig, AmuSession, ctx, workload
from repro.configs.base import EngineConfig
from repro.core.workloads import WorkloadInstance

# --- workload --------------------------------------------------- (41 lines)
CHUNK = 16              # 8B words fetched per vector command, per operand


@workload("DOTV", description="far-memory dot product, vector-loaded chunks")
def build_dotv(seed: int = 0, n: int = 4096,
               coroutines: int = 8) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << 20, size=n).astype(np.float64)
    b = rng.integers(0, 1 << 20, size=n).astype(np.float64)
    mem = np.concatenate([a, b, np.zeros(coroutines)]).view(np.uint8).copy()
    b_off, sum_off = n * 8, 2 * n * 8                # partials live far-side

    def task(c: int, lo: int, hi: int):
        sa = c * 2 * CHUNK * 8                       # a-slots | b-slots
        sb = sa + CHUNK * 8
        acc = 0.0
        for k0 in range(lo, hi, CHUNK):
            cnt = min(CHUNK, hi - k0)
            offs = np.arange(k0, k0 + cnt) * 8
            slots = np.arange(cnt) * 8
            yield ctx.aload_vec(np.concatenate([sa + slots, sb + slots]),
                                np.concatenate([offs, b_off + offs]), 8)
            va = yield ctx.spm_read(sa, cnt * 8)     # zero-copy views
            vb = yield ctx.spm_read(sb, cnt * 8)
            acc += float(va.view(np.float64) @ vb.view(np.float64))
            yield ctx.cost(insts=2 * cnt)
        yield ctx.spm_write(sa, np.float64(acc).tobytes())
        yield ctx.astore(sa, sum_off + c * 8, 8)     # publish the partial

    bounds = np.linspace(0, n, coroutines + 1).astype(int)
    tasks = [task(c, bounds[c], bounds[c + 1]) for c in range(coroutines)]

    def verify(mem_out: np.ndarray) -> bool:
        parts = mem_out[sum_off:sum_off + coroutines * 8].view(np.float64)
        return bool(np.isclose(parts.sum(), float(a @ b)))

    return WorkloadInstance("DOTV", mem, tasks, n,
                            EngineConfig(queue_length=512, granularity=8),
                            verify)
# --- end workload -----------------------------------------------------------


def main() -> None:
    print("DOTV through AmuSession (same port, three configurations):")
    base = AmuConfig(engine="batched", latency_us=1.0)
    for label, cfg in [("batched @1us", base),
                       ("scalar oracle @1us", base.derive(engine="scalar")),
                       ("batched @5us", base.derive(latency_us=5.0))]:
        with AmuSession(cfg) as s:
            st = s.run("DOTV")
            assert st.verified, "dot product wrong!"
            print(f"  {label:>20s}: {st.us:8.1f}us  mlp={st.mlp:5.1f}  "
                  f"requests={st.requests}")
    print("ok: verified under every configuration")


if __name__ == "__main__":
    main()
