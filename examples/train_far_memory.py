"""End-to-end driver: train a (reduced) model for a few hundred steps with
the full substrate — sharded state, gradient accumulation, async
checkpointing, fault injection + automatic restart, straggler monitor.

This is deliverable (b)'s "train ~100M model for a few hundred steps"
scaled to the CPU container; pass --full-size on a real cluster.

Usage: PYTHONPATH=src python examples/train_far_memory.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import synthetic_batch
from repro.models import lm
from repro.optim import adamw
from repro.runtime import steps as steps_mod
from repro.runtime.ft import StepMonitor, TrainSupervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=25,
                    help="inject a node failure at this step (-1: off)")
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    shape = configs.ShapeConfig("train", args.seq, args.batch, "train")
    par = configs.ParallelConfig(remat="full", microbatches=2)
    opt_cfg = adamw.AdamWConfig(learning_rate=1e-3, warmup_steps=10,
                                total_steps=args.steps)

    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init_state(params)
    step_fn = jax.jit(steps_mod.make_train_step(cfg, par, opt_cfg))

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in synthetic_batch(cfg, shape, step).items()}

    monitor = StepMonitor(on_straggler=lambda s, d, e: print(
        f"  [straggler] step {s}: {d * 1e3:.0f}ms vs ewma {e * 1e3:.0f}ms"))
    sup = TrainSupervisor(CheckpointStore(args.ckpt), checkpoint_every=10,
                          monitor=monitor)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps, failure injected at step {args.fail_at}")
    t0 = time.time()
    state = sup.run({"params": params, "opt_state": opt_state, "step": 0},
                    step_fn, batch_fn, args.steps,
                    fail_at=None if args.fail_at < 0 else args.fail_at)
    dt = time.time() - t0
    print(f"done in {dt:.1f}s | final loss {float(state['metrics']['loss']):.4f} "
          f"| restarts survived: {sup.restarts} "
          f"| stragglers: {len(monitor.stragglers)}")


if __name__ == "__main__":
    main()
