"""Quickstart: the paper's AMI programming model in 60 lines.

Runs GUPS (the paper's flagship random-access benchmark) four ways:
  1. synchronous baseline (modeled OoO core),
  2. AMU through the session API — `AmuConfig` + `AmuSession.run` against
     the timed engine (the far-memory table is real data, verified),
  3. a 4-core rack sharing ONE far-memory device (`RackSession`),
  4. the Pallas TPU kernel twin (interpret mode on CPU).

Usage: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.amu import AmuConfig, AmuSession, RackSession
from repro.core import simulator as sim
from repro.kernels import ops, ref

# shrunken rack shape (keeps this example a fast CI smoke; drop the
# kwargs for the paper-sized run)
GUPS_KW = dict(table_words=8192, updates=2048, coroutines=128)


def main() -> None:
    print("=== GUPS under growing far-memory latency ===")
    print(f"{'latency':>8s} {'baseline':>10s} {'AMU':>10s} {'speedup':>8s} "
          f"{'AMU MLP':>8s}")
    for lat in (0.2, 1.0, 5.0):
        base = sim.run("GUPS", "baseline", lat)
        with AmuSession(AmuConfig(latency_us=lat)) as s:
            amu = s.run("GUPS")          # same paper-sized port as baseline
        assert amu.verified, "far-memory contents wrong!"
        print(f"{lat:7.1f}u {base['us']:9.1f}u {amu.us:9.1f}u "
              f"{base['us'] / amu.us:7.2f}x {amu.mlp:8.1f}")

    print("\n=== 4 cores, one shared far-memory device (RackSession) ===")
    with RackSession(AmuConfig(cores=4)) as r:
        rack = r.run("GUPS", **GUPS_KW)
    with AmuSession(AmuConfig()) as s:
        solo = s.run("GUPS", **GUPS_KW)
    assert rack.verified
    occ = rack.link_occupancy["far"]["occupancy"]
    print(f"aggregate {rack.aggregate_gups / (solo.units / solo.us / 1e3):.2f}x"
          f" one core | Jain fairness {rack.fairness:.3f}"
          f" | shared-link occupancy {occ:.1%}")

    print("\n=== the same mechanism as a TPU kernel (interpret mode) ===")
    rng = np.random.default_rng(0)
    table = jnp.array(rng.integers(0, 1 << 30, (4096, 128)), jnp.int32)
    idx = jnp.array(rng.integers(0, 4096, 512), jnp.int32)
    upd = jnp.array(rng.integers(0, 1 << 30, (512, 128)), jnp.int32)
    out = ops.scatter_update(table, idx, upd, op="xor", num_slots=8)
    expect = ref.scatter_update_ref(table, idx, upd, op="xor")
    print("async_scatter (GUPS xor-update, 8 DMA slots in flight):",
          "OK" if bool(jnp.all(out == expect)) else "MISMATCH")

    print("\nThe paper's law: sustained MLP needs latency x bandwidth of "
          "slots;\nthe engine, the coroutine runtime, the rack arbiter and "
          "the kernel\nall implement it.")


if __name__ == "__main__":
    main()
