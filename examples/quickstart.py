"""Quickstart: the paper's AMI programming model in 60 lines.

Runs GUPS (the paper's flagship random-access benchmark) three ways:
  1. synchronous baseline (modeled OoO core),
  2. AMU with the coroutine framework (actually executed against the timed
     engine — the far-memory table is real data, verified at the end),
  3. the Pallas TPU kernel twin (interpret mode on CPU).

Usage: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import simulator as sim
from repro.kernels import ops, ref


def main() -> None:
    print("=== GUPS under growing far-memory latency ===")
    print(f"{'latency':>8s} {'baseline':>10s} {'AMU':>10s} {'speedup':>8s} "
          f"{'AMU MLP':>8s}")
    for lat in (0.2, 1.0, 5.0):
        base = sim.run("GUPS", "baseline", lat)
        amu = sim.run("GUPS", "amu", lat)
        assert amu["verified"], "far-memory contents wrong!"
        print(f"{lat:7.1f}u {base['us']:9.1f}u {amu['us']:9.1f}u "
              f"{base['us'] / amu['us']:7.2f}x {amu['mlp']:8.1f}")

    print("\n=== the same mechanism as a TPU kernel (interpret mode) ===")
    rng = np.random.default_rng(0)
    table = jnp.array(rng.integers(0, 1 << 30, (4096, 128)), jnp.int32)
    idx = jnp.array(rng.integers(0, 4096, 512), jnp.int32)
    upd = jnp.array(rng.integers(0, 1 << 30, (512, 128)), jnp.int32)
    out = ops.scatter_update(table, idx, upd, op="xor", num_slots=8)
    expect = ref.scatter_update_ref(table, idx, upd, op="xor")
    print("async_scatter (GUPS xor-update, 8 DMA slots in flight):",
          "OK" if bool(jnp.all(out == expect)) else "MISMATCH")

    print("\nThe paper's law: sustained MLP needs latency x bandwidth of "
          "slots;\nthe engine, the coroutine runtime, and the kernel all "
          "implement it.")


if __name__ == "__main__":
    main()
