"""Public jit'd wrappers around the Pallas kernels: layout/padding glue and
backend dispatch (interpret=True when running on CPU, compiled on TPU).

The model layer (`repro.models.blocks`) calls these when `use_kernels=True`;
the multi-pod dry-run lowers the pure-jnp reference path instead (Pallas
interpret mode does not compose with SPMD partitioning on the CPU backend —
noted in DESIGN.md), so the kernels are validated standalone against ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.async_gather import async_gather as _gather
from repro.kernels.async_scatter import async_scatter as _scatter
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.stream_triad import stream_triad as _triad


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def gather(table: jnp.ndarray, indices: jnp.ndarray,
           block_m: int = 256, num_slots: int = 8) -> jnp.ndarray:
    """Embedding/GUPS gather: out[i] = table[indices[i]]."""
    idx_p, m = _pad_to(indices.astype(jnp.int32), 0, block_m)
    out = _gather(table, idx_p, block_m=block_m, num_slots=num_slots,
                  interpret=_interpret())
    return out[:m]


def scatter_update(table: jnp.ndarray, indices: jnp.ndarray,
                   updates: jnp.ndarray, op: str = "add",
                   block_m: int = 256, num_slots: int = 8) -> jnp.ndarray:
    """RMW scatter: table[idx[j]] op= updates[j]; pads with a sink row."""
    N, D = table.shape
    idx_p, m = _pad_to(indices.astype(jnp.int32), 0, block_m, value=N)
    upd_p, _ = _pad_to(updates, 0, block_m)
    # sink row N absorbs the padded updates
    table_p = jnp.concatenate([table, jnp.zeros((1, D), table.dtype)], 0)
    out = _scatter(table_p, idx_p, upd_p, op=op, block_m=block_m,
                   num_slots=num_slots, interpret=_interpret())
    return out[:N]


def triad(b: jnp.ndarray, c: jnp.ndarray, s: float,
          block: int = 512) -> jnp.ndarray:
    bp, n = _pad_to(b, 0, block)
    cp, _ = _pad_to(c, 0, block)
    return _triad(bp, cp, s, block=block, interpret=_interpret())[:n]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Model-layer layout: q [B, S, Hq, D], k/v [B, S, Hkv, D] ->
    [B, S, Hq, D]. Pads S to the block size (extra keys are masked by
    causality; extra query rows are sliced off)."""
    Bq = jnp.swapaxes(q, 1, 2)          # [B, Hq, S, D]
    Bk = jnp.swapaxes(k, 1, 2)
    Bv = jnp.swapaxes(v, 1, 2)
    S = Bq.shape[2]
    blk = min(block_q, block_k)
    Bq, _ = _pad_to(Bq, 2, blk)
    Bk, _ = _pad_to(Bk, 2, blk)
    Bv, _ = _pad_to(Bv, 2, blk)
    out = _flash(Bq, Bk, Bv, causal=causal, window=window,
                 block_q=min(block_q, Bq.shape[2]),
                 block_k=min(block_k, Bk.shape[2]),
                 interpret=_interpret())
    return jnp.swapaxes(out[:, :, :S], 1, 2)


def paged_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, lengths: jnp.ndarray,
                    page: int = 512) -> jnp.ndarray:
    """Decode attention. q: [B, Hq, D]; caches [B, T, Hkv, D]; lengths [B]."""
    kp, _ = _pad_to(k_cache, 1, page)
    vp, _ = _pad_to(v_cache, 1, page)
    return _paged(q, kp, vp, lengths.astype(jnp.int32), page=page,
                  interpret=_interpret())


__all__ = ["gather", "scatter_update", "triad", "flash_attention",
           "paged_attention", "ref"]
