"""stream_triad — STREAM triad ``a = b + s*c`` with large-granularity
asynchronous block transfers.

The paper's STREAM port issues 512B+ aloads; on TPU the analogous structure
is the Pallas grid pipeline: each grid step's BlockSpec block is fetched
HBM->VMEM by an async DMA issued ahead of use (double buffering), i.e. the
compiler-managed version of the AMU slot ring. Block size = the `aload`
granularity; the pipeline depth plays the role of `queue_length`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _triad_kernel(s_ref, b_ref, c_ref, a_ref):
    a_ref[...] = b_ref[...] + s_ref[0] * c_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def stream_triad(b: jnp.ndarray, c: jnp.ndarray, s: float,
                 block: int = 512, interpret: bool = False) -> jnp.ndarray:
    """b, c: [N] (N % block == 0) -> a = b + s*c, streamed block by block."""
    (N,) = b.shape
    assert N % block == 0, (N, block)
    lanes = 128
    rows = block // lanes
    assert block % lanes == 0
    b2 = b.reshape(N // lanes, lanes)
    c2 = c.reshape(N // lanes, lanes)
    sv = jnp.array([s], b.dtype)
    out = pl.pallas_call(
        _triad_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(N // block,),
            in_specs=[pl.BlockSpec((rows, lanes), lambda i, s_: (i, 0)),
                      pl.BlockSpec((rows, lanes), lambda i, s_: (i, 0))],
            out_specs=pl.BlockSpec((rows, lanes), lambda i, s_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N // lanes, lanes), b.dtype),
        interpret=interpret,
    )(sv, b2, c2)
    return out.reshape(N)
