"""async_gather — the AMU mechanism as a TPU kernel.

GUPS-gather / embedding-lookup: ``out[i] = table[idx[i]]`` where `table`
lives in HBM ("far memory" relative to VMEM) and rows are random.

This is a direct transcription of the paper's AMI pipeline:

* ``aload``   -> ``pltpu.make_async_copy(table[row], slot[j % K]).start()``
                 issued K rows ahead (request issuing decoupled from use);
* SPM         -> a VMEM slot ring (``K`` slots x row bytes), the repurposed
                 scratch the paper carves out of L2;
* ``getfin``  -> ``.wait()`` on the slot's DMA semaphore right before the
                 row is consumed (completion decoupled from issue);
* request IDs -> slot index ``j mod K``; the free list/finished list
                 degenerate to the ring order because TPU DMAs complete
                 in-order per (src, dst, sem) triple.

K is sized by the latency-bandwidth product (``K * row_bytes >=
HBM_latency * HBM_bw``), exactly the paper's "queue_length follows demand"
rule. The grid is over index blocks so the scalar indices arrive via
scalar prefetch (SMEM) before the block body runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, out_ref, slots, sems, *,
                   block_m: int, num_slots: int):
    """One grid step gathers `block_m` rows through a `num_slots`-deep ring.

    idx_ref: SMEM [M] (scalar-prefetched); table_ref: ANY [N, D];
    out_ref: VMEM [block_m, D]; slots: VMEM [num_slots, D]; sems: DMA [K].
    """
    base = pl.program_id(0) * block_m

    def dma(j, slot):
        row = idx_ref[base + j]
        return pltpu.make_async_copy(
            table_ref.at[pl.ds(row, 1), :],
            slots.at[pl.ds(slot, 1), :],
            sems.at[slot])

    # prime the ring: issue the first K aloads back-to-back (MLP!)
    def prime(j, _):
        dma(j, j % num_slots).start()
        return 0
    jax.lax.fori_loop(0, min(num_slots, block_m), prime, 0)

    def body(j, _):
        slot = j % num_slots
        dma(j, slot).wait()                    # getfin for this slot
        out_ref[pl.ds(j, 1), :] = slots[pl.ds(slot, 1), :]

        @pl.when(j + num_slots < block_m)
        def _():                               # reuse the freed slot
            dma(j + num_slots, slot).start()
        return 0

    jax.lax.fori_loop(0, block_m, body, 0)


@functools.partial(jax.jit, static_argnames=("block_m", "num_slots",
                                             "interpret"))
def async_gather(table: jnp.ndarray, indices: jnp.ndarray,
                 block_m: int = 256, num_slots: int = 8,
                 interpret: bool = False) -> jnp.ndarray:
    """out[i] = table[indices[i]]; table: [N, D], indices: [M] int32.

    M must be a multiple of block_m (ops.py pads).
    """
    M = indices.shape[0]
    N, D = table.shape
    assert M % block_m == 0, (M, block_m)
    grid = (M // block_m,)
    kernel = functools.partial(_gather_kernel, block_m=block_m,
                               num_slots=num_slots)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((block_m, D), lambda i, idx: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((num_slots, D), table.dtype),
                pltpu.SemaphoreType.DMA((num_slots,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((M, D), table.dtype),
        interpret=interpret,
    )(indices, table)
