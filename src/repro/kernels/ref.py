"""Pure-jnp oracles for every Pallas kernel. Tests assert_allclose each
kernel (interpret=True) against these."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def gather_ref(table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """async_gather oracle: out[i] = table[indices[i]]."""
    return table[indices]


def scatter_update_ref(table: jnp.ndarray, indices: jnp.ndarray,
                       updates: jnp.ndarray, op: str = "add") -> jnp.ndarray:
    """async_scatter oracle: read-modify-write, conflicts serialized in
    index order (both add and xor commute, so any serialization matches)."""
    if op == "add":
        return table.at[indices].add(updates)
    if op == "xor":
        def body(i, t):
            return t.at[indices[i]].set(t[indices[i]] ^ updates[i])
        return jax.lax.fori_loop(0, indices.shape[0], body, table)
    raise ValueError(op)


def triad_ref(b: jnp.ndarray, c: jnp.ndarray, s: float) -> jnp.ndarray:
    """STREAM triad oracle: a = b + s * c."""
    return b + s * c


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """flash_attention oracle. q: [B, Hq, S, D]; k/v: [B, Hkv, T, D].
    GQA: q head h attends kv head h // (Hq // Hkv)."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale or 1.0 / math.sqrt(D)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None] + (T - S)   # queries at the sequence tail
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                        v_cache: jnp.ndarray,
                        lengths: jnp.ndarray) -> jnp.ndarray:
    """paged_attention (decode) oracle.
    q: [B, Hq, D]; caches: [B, T, Hkv, D]; lengths: [B] valid prefix."""
    B, Hq, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k_cache, rep, axis=2)       # [B, T, Hq, D]
    v = jnp.repeat(v_cache, rep, axis=2)
    logits = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    mask = jnp.arange(T)[None, :] < lengths[:, None]       # [B, T]
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bht,bthd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
