"""paged_attention — single-token decode attention with KV pages streamed
from HBM ("far memory") through the VMEM pipeline.

This is the serving-side AMU: at decode, the KV cache (32k-512k tokens) is
far memory touched once per token — no reuse, pure latency/bandwidth. The
kernel walks the cache page by page (page = `aload` granularity); the Pallas
grid pipeline keeps multiple page DMAs in flight while the MXU consumes the
previous page (issue/complete decoupling). Pages past the sequence length
are skipped via the scalar-prefetched `lengths`.

Layout: q is grouped by KV head (GQA): [B, Hkv, G, D] so one grid step
computes a whole query group against its single KV head page.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, page: int, scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)
    seq_len = len_ref[b]

    @pl.when(pi == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = pi * page

    @pl.when(start < seq_len)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)         # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        logits = (q @ k.T) * scale                     # [G, page]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(pos < seq_len, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(pi == np_ - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("page", "interpret"))
def paged_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, lengths: jnp.ndarray,
                    page: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, D]; k_cache/v_cache: [B, T, Hkv, D]; lengths: [B] ->
    out [B, Hq, D]."""
    B, Hq, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    page = min(page, T)
    assert T % page == 0, (T, page)
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, T // page)
    kernel = functools.partial(_paged_kernel, page=page, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, pi, L: (b, h, 0, 0)),
                pl.BlockSpec((1, page, 1, D),
                             lambda b, h, pi, L: (b, pi, h, 0)),
                pl.BlockSpec((1, page, 1, D),
                             lambda b, h, pi, L: (b, pi, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, pi, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, Hq, D)
