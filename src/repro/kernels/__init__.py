"""Pallas TPU kernels for the AMU mechanism's compute hot-spots.

Each kernel module pairs with a pure-jnp oracle in ref.py; ops.py holds the
jit'd public wrappers. Validated with interpret=True on CPU; TPU is the
target (pl.pallas_call + BlockSpec VMEM tiling + explicit async DMA).
"""
from repro.kernels import ops, ref
