"""async_scatter — GUPS-update / embedding-grad: read-modify-write rows of an
HBM table through a VMEM slot ring, with CAM-free software disambiguation.

Per update j (paper Fig 4 + §5.1, on TPU):

  1. slot reuse  -> wait the store that last used slot ``j mod K``
                    (drain watermark, the "free list");
  2. conflict    -> compare ``idx[j]`` against the K-1 in-flight store
                    indices (a register ring, not a CAM — §5.1's "only
                    active locations matter"); on a hit, drain stores up to
                    the conflicting one so the aload sees fresh data;
  3. aload       -> async copy ``table[idx[j]] -> slot``;
  4. modify      -> ``slot += update[j]`` (or xor);
  5. astore      -> async copy ``slot -> table[idx[j]]``, retire immediately.

Loads are issued K ahead of use; stores drain lazily. The watermark (kept in
SMEM) guarantees each store semaphore is waited exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(idx_ref, upd_ref, table_in_ref, out_ref, slots_ld,
                    slots_st, load_sems, store_sems, wm_ref, *,
                    block_m: int, num_slots: int, op: str):
    base = pl.program_id(0) * block_m
    K = num_slots
    del table_in_ref  # aliased with out_ref; all access goes through out_ref

    def load_dma(j):
        row = idx_ref[base + j]
        return pltpu.make_async_copy(out_ref.at[pl.ds(row, 1), :],
                                     slots_ld.at[pl.ds(j % K, 1), :],
                                     load_sems.at[j % K])

    def store_dma(j):
        row = idx_ref[base + j]
        return pltpu.make_async_copy(slots_st.at[pl.ds(j % K, 1), :],
                                     out_ref.at[pl.ds(row, 1), :],
                                     store_sems.at[j % K])

    def drain_to(j_req):
        """Wait every store with index in (watermark, j_req]."""
        def wait_one(t, _):
            store_dma(t).wait()
            return 0
        wm = wm_ref[0]
        jax.lax.fori_loop(wm + 1, j_req + 1, wait_one, 0)
        wm_ref[0] = jnp.maximum(wm, j_req)

    wm_ref[0] = jnp.int32(-1)

    def prime(j, _):
        load_dma(j).start()
        return 0
    jax.lax.fori_loop(0, min(K, block_m), prime, 0)

    def body(j, _):
        slot = j % K
        load_dma(j).wait()
        # CAM-free software disambiguation (§5.1) at consume time: if any
        # store in (watermark, j) targets this row, the speculative aload
        # read stale data -> drain to the youngest conflicting store and
        # re-load synchronously. Conflicts are rare (the paper's premise),
        # so the common path stays fully pipelined.
        my_row = idx_ref[base + j]

        def scan(t, acc):
            hit = idx_ref[base + t] == my_row
            return jnp.where(hit, jnp.maximum(acc, t), acc)
        # candidates: stores that may not have completed before THIS load was
        # issued (load j issues at step j-K; by then stores <= j-2K had been
        # drained) -> scan the last 2K-1 indices, not from the watermark.
        h = jax.lax.fori_loop(jnp.maximum(0, j - 2 * K + 1), j, scan,
                              jnp.int32(-1))

        @pl.when(h >= 0)
        def _():
            drain_to(h)
            load_dma(j).start()
            load_dma(j).wait()
        # store-slot reuse: the store that used this slot (j-K) must be done
        @pl.when(j >= K)
        def _():
            drain_to(j - K)
        if op == "add":
            slots_st[pl.ds(slot, 1), :] = (slots_ld[pl.ds(slot, 1), :]
                                           + upd_ref[pl.ds(j, 1), :])
        else:  # xor
            slots_st[pl.ds(slot, 1), :] = (slots_ld[pl.ds(slot, 1), :]
                                           ^ upd_ref[pl.ds(j, 1), :])
        store_dma(j).start()

        @pl.when(j + K < block_m)
        def _():
            load_dma(j + K).start()
        return 0

    jax.lax.fori_loop(0, block_m, body, 0)
    drain_to(block_m - 1)         # retire everything before the block ends


@functools.partial(jax.jit, static_argnames=("block_m", "num_slots", "op",
                                             "interpret"))
def async_scatter(table: jnp.ndarray, indices: jnp.ndarray,
                  updates: jnp.ndarray, op: str = "add",
                  block_m: int = 256, num_slots: int = 8,
                  interpret: bool = False) -> jnp.ndarray:
    """Returns table with rows RMW-updated: table[idx[j]] op= updates[j]."""
    M = indices.shape[0]
    N, D = table.shape
    assert M % block_m == 0, (M, block_m)
    assert updates.shape == (M, D)
    grid = (M // block_m,)
    kernel = functools.partial(_scatter_kernel, block_m=block_m,
                               num_slots=num_slots, op=op)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, D), lambda i, idx: (i, 0)),  # updates
                pl.BlockSpec(memory_space=pl.ANY),               # table
            ],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            scratch_shapes=[
                pltpu.VMEM((num_slots, D), table.dtype),
                pltpu.VMEM((num_slots, D), table.dtype),
                pltpu.SemaphoreType.DMA((num_slots,)),
                pltpu.SemaphoreType.DMA((num_slots,)),
                pltpu.SMEM((1,), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(indices, updates, table)
