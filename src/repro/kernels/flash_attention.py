"""flash_attention — blockwise causal/windowed attention (train & prefill).

Grid: (batch, q_head, S/BQ, T/BK) with the KV dimension innermost; running
softmax statistics (m, l) and the output accumulator persist in VMEM scratch
across KV steps and are finalized on the last one. GQA is handled in the
BlockSpec index maps (q head h reads kv head h // group).

The KV blocks stream HBM->VMEM through the Pallas pipeline (async DMA issued
a step ahead) — the AMU slot ring in its compiler-managed form; BlockSpec
shapes are chosen so both MXU operands are 128-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip fully-masked blocks (upper triangle / outside the window)
    run = jnp.asarray(True)
    if causal:
        run = run & (k_start <= q_start + block_q - 1)
    if window:
        run = run & (k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)           # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)           # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)
        logits = (q @ k.T) * scale                    # [BQ, BK]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)                   # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    scale = 1.0 / math.sqrt(D)
    grid = (B, Hq, S // block_q, S // block_k)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, seq_len=S, causal=causal,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
