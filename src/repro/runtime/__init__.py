# NOTE: submodules are imported directly (repro.runtime.steps etc.);
# importing them here would create a models <-> runtime import cycle via
# the sharding-hints module used inside model code.
