"""Step builders: jit-able train_step / prefill / serve_step closures with
donation and sharding attached — shared by the real train loop, the serving
loop, and the multi-pod dry-run (which lowers exactly these functions).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import lm
from repro.optim import adamw
from repro.runtime import sharding as shd

Params = Any


def make_train_step(cfg: ModelConfig, par: ParallelConfig,
                    opt_cfg: adamw.AdamWConfig,
                    use_kernels: bool = False,
                    moe_mode: str = "capacity") -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    par.microbatches > 1 -> gradient accumulation: the global batch is split
    along the batch dim and scanned, with full remat inside each microstep;
    activation peak shrinks ~1/n at the cost of re-walking the weights.
    """
    n_micro = max(par.microbatches, 1)

    def loss_fn(p, mb):
        loss, metrics = lm.train_loss(
            cfg, p, mb, use_kernels=use_kernels, moe_mode=moe_mode,
            remat=par.remat)
        return loss, metrics

    def train_step(params: Params, opt_state: Dict[str, Any],
                   batch: Dict[str, jnp.ndarray]):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda t: t.reshape((n_micro, t.shape[0] // n_micro)
                                    + t.shape[1:]), batch)
            gzero = jax.tree.map(
                lambda t: jnp.zeros(t.shape, jnp.float32), params)

            def mb_step(carry, mb):
                gacc, lacc = carry
                (loss, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g)
                return (gacc, lacc + loss), None

            (gsum, lsum), _ = jax.lax.scan(
                mb_step, (gzero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
            metrics = {}
        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, par: ParallelConfig,
                      use_kernels: bool = False,
                      moe_mode: str = "capacity") -> Callable:
    def prefill_step(params: Params, batch: Dict[str, jnp.ndarray],
                     cache: Params):
        return lm.prefill(cfg, params, batch, cache,
                          use_kernels=use_kernels, moe_mode=moe_mode)
    return prefill_step


def make_serve_step(cfg: ModelConfig, par: ParallelConfig,
                    use_kernels: bool = False,
                    moe_mode: str = "capacity") -> Callable:
    """One decode step: (params, tokens [B,1], cache) -> (logits, cache)."""
    def serve_step(params: Params, tokens: jnp.ndarray, cache: Params):
        return lm.decode_step(cfg, params, tokens, cache,
                              use_kernels=use_kernels, moe_mode=moe_mode)
    return serve_step


# ------------------------------------------------------------ jit packaging
def jit_train_step(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                   opt_cfg: adamw.AdamWConfig, params: Params,
                   opt_state: Params, shape: ShapeConfig,
                   use_kernels: bool = False, moe_mode: str = "capacity"):
    """jit with explicit in/out shardings + donation of params/opt_state."""
    p_sh = shd.params_shardings(cfg, par, mesh, params)
    o_sh = shd.opt_state_shardings(cfg, par, mesh, params)
    b_sh = shd.batch_shardings(cfg, par, mesh, shape)
    step = make_train_step(cfg, par, opt_cfg, use_kernels, moe_mode)
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if par.donate_state else (),
    ), p_sh, o_sh, b_sh
