"""Sharding hints for activations inside model code.

Model code is mesh-agnostic; the launcher registers the active mesh axis
sizes before tracing (`set_mesh_axes`), and `constrain` applies
`with_sharding_constraint` only when (a) axes are registered and (b) every
named axis divides the corresponding dim. Otherwise it is the identity, so
tests and single-device runs are untouched.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def set_mesh_axes(axes: Optional[Dict[str, int]], mesh=None) -> None:
    _state.axes = dict(axes) if axes else None
    _state.mesh = mesh


def set_mesh(mesh) -> None:
    set_mesh_axes({k: v for k, v in mesh.shape.items()}, mesh)


def get_mesh():
    return getattr(_state, "mesh", None)


def get_mesh_axes() -> Optional[Dict[str, int]]:
    return getattr(_state, "axes", None)


def axis_size(name: Union[str, Sequence[str]]) -> int:
    axes = get_mesh_axes() or {}
    if isinstance(name, str):
        return axes.get(name, 1)
    n = 1
    for a in name:
        n *= axes.get(a, 1)
    return n


def batch_spec_axes():
    axes = get_mesh_axes() or {}
    return ("pod", "data") if "pod" in axes else ("data",)


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) when legal, else identity.
    Each spec entry: None | axis name | tuple of axis names."""
    axes = get_mesh_axes()
    if axes is None:
        return x
    clean = []
    for dim, entry in zip(x.shape, spec):
        if entry is None:
            clean.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        if not all(n in axes for n in names):
            clean.append(None)
            continue
        size = 1
        for n in names:
            size *= axes[n]
        clean.append(entry if dim % size == 0 else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*clean))
    except Exception:  # no mesh context at trace time
        return x
