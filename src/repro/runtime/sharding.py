"""Sharding rules: PartitionSpecs for params, optimizer state, caches, and
batches on the production meshes ("pod", "data", "model").

Conventions
-----------
* DP/batch: ("pod", "data") — gradients all-reduce across both axes.
* TP: "model" — attention heads / FFN hidden / vocab / experts.
* FSDP (big models): params additionally sharded over "data" on the largest
  non-TP dimension; XLA SPMD inserts the per-layer all-gather inside the
  scan and reduce-scatters the grads.
* ZeRO-1: optimizer moments follow the FSDP spec even when params are
  replicated (zero1 flag) — each data shard owns a slice of m/v.
* Sequence parallel: for prefill/long-context cells whose batch cannot
  cover the data axis, the sequence dimension shards over "data".

Leaf classification is name-based over the param pytree paths, mirroring
how production frameworks (MaxText et al.) declare logical axis rules.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, ParallelConfig,
                                ShapeConfig)

Params = Any


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _divisible(n: int, mesh: Mesh, *axes: str) -> bool:
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


def param_spec(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
               path: str, leaf) -> P:
    """PartitionSpec for one parameter leaf (path from tree_flatten_with_path).

    Stacked scan params have a leading period/layer axis that is never
    sharded; the rules below address the trailing dims.
    """
    dp = batch_axes(mesh)
    shape = leaf.shape
    nd = len(shape)

    def fsdp_axis(tp_dim: Optional[int]) -> Optional[int]:
        """Pick the largest non-TP trailing dim divisible by the data axes."""
        if not par.fsdp:
            return None
        best, best_dim = None, 0
        start = 1 if nd >= 3 else 0      # skip the stacked layer axis
        for i in range(start, nd):
            if i == tp_dim:
                continue
            if shape[i] > best_dim and _divisible(shape[i], mesh, *dp):
                best, best_dim = i, shape[i]
        return best

    def spec_with(tp_dim: Optional[int]) -> P:
        axes = [None] * nd
        if tp_dim is not None and _divisible(shape[tp_dim], mesh, "model"):
            axes[tp_dim] = "model"
        else:
            tp_dim = None
        fa = fsdp_axis(tp_dim)
        if fa is not None:
            axes[fa] = dp if len(dp) > 1 else dp[0]
        return P(*axes)

    # ---- embedding / head: vocab over model --------------------------------
    if re.search(r"\['embed'\]|\['head'\]", path):
        vocab_dim = next((i for i, s in enumerate(shape)
                          if s == cfg.vocab_size), 0)
        return spec_with(vocab_dim)
    # ---- MoE experts: expert dim over model (expert parallelism) -----------
    if re.search(r"\['ffn'\].*\['(w_gate|w_up|w_down)'\]", path) \
            and cfg.moe is not None and nd >= 3:
        axes = [None] * nd
        e_dim = nd - 3                   # [..., E, in, out]
        if par.expert_parallel and _divisible(shape[e_dim], mesh, "model"):
            axes[e_dim] = "model"
            if par.fsdp and _divisible(shape[nd - 1], mesh, *dp):
                axes[nd - 1] = dp if len(dp) > 1 else dp[0]
        return P(*axes)
    if "router" in path:
        return P(*([None] * nd))
    # ---- attention projections: heads (fused out dim) over model -----------
    if re.search(r"\['mix'\].*\['w(q|k|v)'\]", path):
        return spec_with(nd - 1)
    if re.search(r"\['mix'\].*\['wo'\]", path):
        return spec_with(nd - 2)         # input dim = heads*hd
    if re.search(r"\['mix'\].*\['b(q|k|v)'\]", path):
        axes = [None] * nd
        if _divisible(shape[-1], mesh, "model"):
            axes[-1] = "model"
        return P(*axes)
    # ---- recurrent mixers: width over model ---------------------------------
    if re.search(r"\['mix'\].*\['(w_x|w_y|w_r|w_i|w_k|w_v|w_g|w_w)'\]", path):
        return spec_with(nd - 1)
    if re.search(r"\['mix'\].*\['(w_out|w_o)'\]", path):
        return spec_with(nd - 2)
    # ---- MLP: hidden over model ---------------------------------------------
    if re.search(r"\['ffn'\].*\['(w_gate|w_up)'\]", path):
        return spec_with(nd - 1)
    if re.search(r"\['ffn'\].*\['w_down'\]", path):
        return spec_with(nd - 2)
    # ---- everything else (norms, small vectors): replicated ----------------
    return P(*([None] * nd))


def params_shardings(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                     params: Params) -> Params:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        spec = param_spec(cfg, par, mesh, jax.tree_util.keystr(path), leaf)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                        params: Params) -> Dict[str, Any]:
    """ZeRO-1: moments follow FSDP placement even if params replicate."""
    zpar = par if par.fsdp else (
        ParallelConfig(**{**par.__dict__, "fsdp": par.zero1}))
    m = params_shardings(cfg, zpar, mesh, params)
    return {"m": m, "v": m,
            "step": NamedSharding(mesh, P())}


def batch_shardings(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                    shape: ShapeConfig) -> Dict[str, NamedSharding]:
    dp = batch_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    B = shape.global_batch
    dsize = int(np.prod([mesh.shape[a] for a in dp]))
    shard_batch = B % dsize == 0
    seq_axis = None
    if (par.seq_shard and not shard_batch and shape.kind == "prefill"
            and shape.seq_len % mesh.shape["model"] == 0):
        seq_axis = "model"
    b_axis = dp_spec if shard_batch else None

    def ns(*axes):
        return NamedSharding(mesh, P(*axes))

    out = {
        "tokens": ns(b_axis, seq_axis),
        "labels": ns(b_axis, seq_axis),
        "features": ns(b_axis, seq_axis, None),
        "vision_embeds": ns(b_axis, None, None),
    }
    return out


def cache_shardings(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                    cache: Params) -> Params:
    """KV caches: batch over data axes; sequence (T) over "model" when the
    batch cannot cover the mesh (decode_32k/long_500k flash-decode style);
    recurrent states: width/heads over "model"."""
    dp = batch_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    dsize = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape["model"]

    def leaf_spec(path: str, leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        if "len" in path:
            return P()
        # scan caches carry a leading period axis: [P, B, ...]; tail caches
        # start at the batch dim.
        off = 1 if "'scan'" in path else 0
        axes = [None] * nd
        if "'k'" in path or "'v'" in path:
            b_i, t_i, h_i = off, off + 1, off + 2
            if shape[b_i] % dsize == 0:
                axes[b_i] = dp_spec
            if shape[t_i] % msize == 0 and shape[h_i] % msize != 0:
                axes[t_i] = "model"
            elif shape[h_i] % msize == 0:
                axes[h_i] = "model"
            return P(*axes)
        if "'s'" in path:      # rwkv6 state [P, B, H, N, N]
            if shape[off] % dsize == 0:
                axes[off] = dp_spec
            if nd > off + 1 and shape[off + 1] % msize == 0:
                axes[off + 1] = "model"
            return P(*axes)
        if "'h'" in path or "conv" in path or "last_x" in path:
            b_i = off if nd > off else 0
            if nd and shape[b_i] % dsize == 0:
                axes[b_i] = dp_spec
            if shape[nd - 1] % msize == 0:
                axes[nd - 1] = "model"
            return P(*axes)
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [NamedSharding(mesh, leaf_spec(jax.tree_util.keystr(p), l))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
