"""Host-DRAM far-memory tier: the paper's mechanism at runtime granularity.

KV pages (or optimizer shards / expert weights) live in host memory — true
microsecond-latency far memory from the accelerator's viewpoint. The
:class:`OffloadedKVCache` keeps only a window of layers resident on device
and uses the AMI pattern to hide transfer latency:

* ``aload``  -> issue the *next* layers' page uploads while the current
  layer computes (a worker thread + ``jax.device_put``, the runtime twin of
  ``pltpu.make_async_copy(...).start()``);
* ``getfin`` -> ``fetch()`` blocks only if the prefetch hasn't landed
  (poll/complete decoupled from issue);
* slot ring  -> the resident window (``window`` layers), recycled in layer
  order like the kernels' VMEM rings;
* writeback  -> updated pages retire to host asynchronously.

The scheduling structure is identical on a real TPU (host<->HBM DMA); on
this CPU container device==host, so the demo exercises the bookkeeping and
overlap logic, and tests assert correctness + issue-ahead behavior.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


class OffloadedKVCache:
    def __init__(self, num_layers: int, window: int = 2,
                 max_retries: int = 0, retry_backoff_s: float = 0.01):
        """``max_retries`` bounds how often a failed prefetch upload is
        re-spawned (exponential ``retry_backoff_s * 2**attempt`` sleep
        between attempts) before the error propagates; the default 0 keeps
        the propagate-immediately behavior. Retries re-read the host page,
        so a transient worker fault (or a late ``host_put``) recovers."""
        assert window >= 1
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {retry_backoff_s}")
        self.num_layers = num_layers
        self.window = window
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self._host: List[Optional[Any]] = [None] * num_layers  # far memory
        self._resident: Dict[int, Any] = {}                    # device slots
        self._dirty: set = set()                               # update()d layers
        self._pending: Dict[int, "queue.Queue"] = {}           # in-flight
        self._writeback_q: "queue.Queue" = queue.Queue()
        self._wb_thread = threading.Thread(target=self._writeback_loop,
                                           daemon=True)
        self._wb_thread.start()
        self.stats = {"prefetch_issued": 0, "prefetch_hits": 0,
                      "demand_fetches": 0, "writebacks": 0,
                      "prefetch_retries": 0}

    # ------------------------------------------------------------- far side
    def host_put(self, layer: int, page: Any) -> None:
        self._host[layer] = np.asarray(page)

    def _writeback_loop(self) -> None:
        while True:
            item = self._writeback_q.get()
            if item is None:
                return
            layer, page = item
            self._host[layer] = np.asarray(jax.device_get(page))
            self.stats["writebacks"] += 1
            self._writeback_q.task_done()

    # ------------------------------------------------------------ AMI-style
    def _upload(self, layer: int, host_page: Any) -> Any:
        """The device copy itself — one seam for tests to make flaky."""
        if host_page is None:
            raise RuntimeError(f"layer {layer} fetched before host_put()")
        return jax.device_put(host_page)

    def _spawn_upload(self, layer: int, q: "queue.Queue") -> None:
        # the worker must never die without posting: a bare put of the
        # device_put result hangs every later fetch() of this layer when the
        # upload raises (e.g. the layer was never host_put). Post the
        # exception instead and re-raise it on the consuming side.
        host_page = self._host[layer]

        def work():
            try:
                q.put(("ok", self._upload(layer, host_page)))
            except BaseException as exc:  # noqa: BLE001 - posted, not dropped
                q.put(("err", exc))

        threading.Thread(target=work, daemon=True).start()

    def prefetch(self, layer: int) -> None:
        """aload: issue the upload of `layer`'s page; returns immediately."""
        if layer >= self.num_layers or layer in self._resident \
                or layer in self._pending:
            return
        q: "queue.Queue" = queue.Queue(maxsize=1)
        self._pending[layer] = q
        self.stats["prefetch_issued"] += 1
        self._spawn_upload(layer, q)

    def _take_pending(self, layer: int) -> Any:
        """Consume `layer`'s in-flight transfer, re-raising a worker error
        after `max_retries` bounded-backoff re-spawns (each retry re-reads
        the current host page, so transient faults recover)."""
        status, payload = self._pending.pop(layer).get()
        attempt = 0
        while status == "err" and attempt < self.max_retries:
            time.sleep(self.retry_backoff_s * (2.0 ** attempt))
            attempt += 1
            self.stats["prefetch_retries"] += 1
            q: "queue.Queue" = queue.Queue(maxsize=1)
            self._spawn_upload(layer, q)
            status, payload = q.get()
        if status == "err":
            raise RuntimeError(
                f"prefetch of layer {layer} failed "
                f"(after {attempt} retries)") from payload
        return payload

    def fetch(self, layer: int) -> Any:
        """getfin + SPM read: returns the resident page, waiting only if the
        issued transfer has not completed yet."""
        if layer in self._resident:
            self.stats["prefetch_hits"] += 1
        elif layer in self._pending:
            self._resident[layer] = self._take_pending(layer)
            self.stats["prefetch_hits"] += 1
        else:
            if self._host[layer] is None:
                raise RuntimeError(
                    f"layer {layer} fetched before host_put()")
            self.stats["demand_fetches"] += 1
            self._resident[layer] = jax.device_put(self._host[layer])
        # keep the window: issue the next prefetch, retire the oldest
        self.prefetch(layer + 1)
        while len(self._resident) > self.window:
            oldest = min(self._resident)
            if oldest == layer:
                break
            self._retire(oldest)
        return self._resident[layer]

    def _retire(self, layer: int) -> None:
        """Evict `layer` from the window: write back only if update()d —
        a clean page is already byte-identical on the host side."""
        page = self._resident.pop(layer)
        if layer in self._dirty:
            self._dirty.discard(layer)
            self._writeback_q.put((layer, page))

    def update(self, layer: int, page: Any) -> None:
        """astore: replace the resident page; writeback happens lazily when
        the slot is recycled."""
        self._resident[layer] = page
        self._dirty.add(layer)

    def flush(self) -> None:
        # land in-flight prefetches first: a pending layer still owns a
        # worker thread and a device copy, and dropping its queue here used
        # to leak both. A landed prefetch is clean by definition (update()
        # targets resident layers), so it retires without a writeback.
        for layer in sorted(self._pending):
            try:
                self._resident[layer] = self._take_pending(layer)
            except RuntimeError:
                pass  # upload failed: the host copy is still authoritative
        for layer in sorted(self._resident):
            self._retire(layer)
        self._writeback_q.join()

    def close(self) -> None:
        self.flush()
        self._writeback_q.put(None)
        self._wb_thread.join(timeout=2.0)
