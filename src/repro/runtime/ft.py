"""Fault tolerance + straggler mitigation.

* :class:`StepMonitor` — per-step wall-time EWMA; flags straggling steps
  (slow host / slow interconnect) and exposes a rebalance hook. On a real
  multi-host deployment the same numbers come from cross-host allgathered
  heartbeats; the detection/mitigation logic is identical.
* :class:`TrainSupervisor` — checkpoint/restart driver: periodic async
  checkpoints, failure injection for tests, resume from the latest manifest
  onto a (possibly different) mesh = elastic restart.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.store import CheckpointStore


@dataclass
class StepMonitor:
    ewma_alpha: float = 0.2
    straggler_factor: float = 2.0
    warmup: int = 3
    ewma: float = 0.0
    steps: int = 0
    stragglers: List[int] = field(default_factory=list)
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def record(self, step: int, duration_s: float) -> bool:
        self.steps += 1
        if self.steps <= self.warmup:
            self.ewma = duration_s if self.ewma == 0.0 else (
                0.5 * (self.ewma + duration_s))
            return False
        is_straggler = duration_s > self.straggler_factor * self.ewma
        if is_straggler:
            self.stragglers.append(step)
            if self.on_straggler:
                self.on_straggler(step, duration_s, self.ewma)
        else:
            self.ewma = (1 - self.ewma_alpha) * self.ewma \
                + self.ewma_alpha * duration_s
        return is_straggler


class SimulatedFailure(RuntimeError):
    pass


class TrainSupervisor:
    """Runs `step_fn` with periodic checkpoints; survives injected failures
    by restoring the latest checkpoint and continuing — the restart path is
    the same code a cluster scheduler would re-enter after a node loss."""

    def __init__(self, store: CheckpointStore, checkpoint_every: int = 50,
                 monitor: Optional[StepMonitor] = None):
        self.store = store
        self.every = checkpoint_every
        self.monitor = monitor or StepMonitor()
        self.restarts = 0

    def run(self, state: Dict[str, Any], step_fn: Callable,
            batch_fn: Callable, total_steps: int,
            fail_at: Optional[int] = None,
            restore_fn: Optional[Callable] = None) -> Dict[str, Any]:
        """state: {"params", "opt_state", "step"}; step_fn(params, opt_state,
        batch) -> (params, opt_state, metrics); batch_fn(step) -> batch.
        `fail_at` injects a failure once at that step (tests)."""
        failed_once = False
        while state["step"] < total_steps:
            step = state["step"]
            try:
                if fail_at is not None and step == fail_at and not failed_once:
                    failed_once = True
                    raise SimulatedFailure(f"injected at step {step}")
                t0 = time.monotonic()
                params, opt_state, metrics = step_fn(
                    state["params"], state["opt_state"], batch_fn(step))
                self.monitor.record(step, time.monotonic() - t0)
                state = {"params": params, "opt_state": opt_state,
                         "step": step + 1, "metrics": metrics}
                if (step + 1) % self.every == 0:
                    self.store.save(step + 1,
                                    {"params": state["params"],
                                     "opt_state": state["opt_state"]},
                                    extra={"step": step + 1})
            except SimulatedFailure:
                self.restarts += 1
                latest = self.store.latest_step()
                if latest is None:
                    state = {**state, "step": 0}
                    continue
                like = {"params": state["params"],
                        "opt_state": state["opt_state"]}
                restored, extra = self.store.restore(
                    latest, like,
                    sharding_fn=restore_fn)
                state = {"params": restored["params"],
                         "opt_state": restored["opt_state"],
                         "step": extra["step"]}
        self.store.wait()
        return state
