"""Pluggable workload registry: the ``@workload`` decorator + Port protocol.

One registry replaces the old ``WORKLOADS`` dict / ``VECTOR_WORKLOADS``
frozenset pair: each entry is a :class:`WorkloadDef` that names its builder,
its baseline :class:`~repro.core.workloads.IterationProfile`, and its
*capabilities* — whether it carries a vector (``AloadVec``/``AstoreVec``)
port, whether that port is a software-pipelined chase (``pipeline_k`` knob),
whether it uses Acquire/Release disambiguation, whether it supports a
``distinct=`` determinism knob, and any LLVM-mode rebuild kwargs. The
session layer (:class:`repro.amu.AmuSession`) consults these capabilities
instead of hard-coding workload names.

Adding a new scenario is one decorated builder function::

    @workload("MYWL", profile=IterationProfile(insts=10, indep_loads=1),
              description="my far-memory scan")
    def build_mywl(seed: int = 0, n: int = 4096) -> WorkloadInstance:
        ...

after which ``AmuSession(cfg).run("MYWL")`` just works — see
``examples/amu_workload.py`` for a complete worked example.

This module is import-cycle-free by design: it depends on nothing inside
``repro`` (the Port protocol is structural), so both ``repro.core`` and
``repro.amu`` can import it freely.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterator, List, Mapping, Optional,
                    Protocol, Tuple, runtime_checkable)


@runtime_checkable
class Port(Protocol):
    """What :meth:`repro.amu.AmuSession.run` needs from a built workload.

    ``WorkloadInstance`` satisfies this structurally; any user object with
    these attributes runs through the session the same way. Frontier-
    parallel ports (BFS) additionally provide ``make_round_tasks(frontier)``,
    ``next_frontier`` and ``root``, and instances built through
    :meth:`WorkloadRegistry.build` carry a ``vector`` attribute recording
    which port was selected — all detected by attribute, not declared here,
    so minimal ports need no stubs.
    """
    name: str
    mem: Any                      # numpy uint8 far-memory backing
    tasks: List                   # generator tasks yielding AMI commands
    units: int                    # logical work units (for rates)
    engine_config: Any            # EngineConfig the port was sized for
    verify: Callable[[Any], bool]
    disambiguation: bool


@dataclass(frozen=True)
class WorkloadDef:
    """A registered workload: builder + profile + declared capabilities."""
    name: str
    build: Callable[..., Port]            # (seed, **knobs) -> Port
    profile: Any = None                   # IterationProfile (window model)
    description: str = ""
    # capabilities ---------------------------------------------------------
    vector: bool = False        # has an AloadVec/AstoreVec port (vector=True)
    pipelined: bool = False     # vector port is a pipelined chase (pipeline_k)
    locked: bool = False        # uses Acquire/Release disambiguation
    distinct: bool = False      # supports the distinct= determinism knob
    frontier: bool = False      # level-synchronous (make_round_tasks driver)
    request_level: bool = False  # open-loop arrivals + per-request latency;
    #                              excluded from throughput-normalized sweeps
    #                              (its cycles include arrival-horizon idle)
    llvm_defaults: Optional[Mapping[str, Any]] = None  # llvm-mode rebuild kw
    defaults: Mapping[str, Any] = field(default_factory=dict)  # default sizes


class WorkloadRegistry:
    """Name -> :class:`WorkloadDef` mapping with capability-aware builds."""

    def __init__(self) -> None:
        self._defs: Dict[str, WorkloadDef] = {}

    def register(self, wd: WorkloadDef) -> WorkloadDef:
        if wd.name in self._defs:
            raise ValueError(f"workload {wd.name!r} already registered")
        self._defs[wd.name] = wd
        return wd

    def __getitem__(self, name: str) -> WorkloadDef:
        try:
            return self._defs[name]
        except KeyError:
            raise KeyError(f"unknown workload {name!r}; "
                           f"known: {sorted(self._defs)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._defs

    def __iter__(self) -> Iterator[str]:
        return iter(self._defs)

    def __len__(self) -> int:
        return len(self._defs)

    def names(self) -> List[str]:
        return list(self._defs)

    def items(self) -> Iterator[Tuple[str, WorkloadDef]]:
        return iter(self._defs.items())

    def vector_names(self) -> List[str]:
        return [n for n, d in self._defs.items() if d.vector]

    def source_files(self) -> List[str]:
        """Deduplicated source files of every registered builder, for
        static analysis (``tools/amilint.py``). Builders whose source is
        unavailable (C extensions, REPL definitions) are skipped."""
        import inspect

        seen: Dict[str, None] = {}
        for wd in self._defs.values():
            try:
                path = inspect.getsourcefile(wd.build)
            except TypeError:
                path = None
            if path:
                seen.setdefault(path)
        return list(seen)

    def build(self, name: str, seed: int = 0, *, vector: bool = False,
              llvm_mode: bool = False, pipeline_k: Optional[int] = None,
              **knobs: Any) -> Port:
        """Build a workload instance honouring declared capabilities.

        ``vector=True`` selects the vector port only where one is declared
        (mirroring the old ``spec.name in VECTOR_WORKLOADS`` guard);
        ``pipeline_k`` reaches only pipelined ports; ``llvm_mode`` rebuilds
        with the workload's declared LLVM-lowering kwargs (scalar port —
        the current LLVM pass emits no vector AMIs).
        """
        wd = self[name]
        kw = dict(wd.defaults)
        kw.update(knobs)
        use_vector = False
        if llvm_mode and wd.llvm_defaults is not None:
            kw.update(wd.llvm_defaults)      # scalar port, LLVM lowering
        elif vector and wd.vector:
            use_vector = True
            kw["vector"] = True
            if pipeline_k is not None and wd.pipelined:
                kw["pipeline_k"] = pipeline_k
        inst = wd.build(seed, **kw)
        if getattr(inst, "vector", None) is None:
            # stamp which port was actually selected, so downstream stats
            # label the run truthfully even when the instance is handed to
            # a session whose config differs
            inst.vector = use_vector         # type: ignore[attr-defined]
        return inst


#: The process-wide registry the built-in workloads register into.
REGISTRY = WorkloadRegistry()


def workload(name: str, *, profile: Any = None, description: str = "",
             registry: WorkloadRegistry = REGISTRY,
             **capabilities: Any) -> Callable[[Callable[..., Port]],
                                              Callable[..., Port]]:
    """Register a builder function as a workload (decorator form).

    ``capabilities`` are the :class:`WorkloadDef` capability fields
    (``vector=``, ``pipelined=``, ``locked=``, ``distinct=``, ``frontier=``,
    ``llvm_defaults=``, ``defaults=``).
    """
    def deco(fn: Callable[..., Port]) -> Callable[..., Port]:
        registry.register(WorkloadDef(name=name, build=fn, profile=profile,
                                      description=description,
                                      **capabilities))
        return fn
    return deco
