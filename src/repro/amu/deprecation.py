"""Deprecation plumbing for the legacy (pre-session) AMU surface.

The old entry points (``simulator.run_amu``, the ``WORKLOADS`` /
``VECTOR_WORKLOADS`` module dicts) keep working as thin shims over
:class:`repro.amu.AmuSession`, but every use emits
:class:`AmuDeprecationWarning`. CI runs a job with this warning promoted to
an error (``-W error::repro.amu.deprecation.AmuDeprecationWarning``) so no
in-repo caller can silently depend on the shimmed surface; the dedicated
shim tests opt back in with ``pytest.warns``.
"""
from __future__ import annotations

import warnings


class AmuDeprecationWarning(DeprecationWarning):
    """A deprecated pre-``AmuSession`` AMU entry point was used."""


def warn_deprecated(old: str, new: str, stacklevel: int = 3) -> None:
    warnings.warn(f"{old} is deprecated; use {new} instead "
                  f"(see TESTING.md's migration table)",
                  AmuDeprecationWarning, stacklevel=stacklevel)
