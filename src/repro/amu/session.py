"""`AmuSession` — one object owning engine + scheduler + far-memory
lifecycle, with ``session.run(port) -> RunStats``.

The session replaces the ad-hoc build-engine-build-scheduler-run-drain
choreography that used to be copy-pasted between ``run_amu``, the benchmark
drivers and the test suites::

    from repro.amu import AmuConfig, AmuSession

    with AmuSession(AmuConfig(engine="batched", vector=True)) as s:
        stats = s.run("GUPS")            # registered workload by name
        assert stats.verified
        mem = s.engine.mem               # engine/far/instance stay inspectable

``run`` accepts a registered workload name or any prebuilt
:class:`~repro.amu.registry.Port` (e.g. a ``WorkloadInstance`` built with
custom knobs). Frontier-parallel ports (BFS) are driven level-
synchronously; everything else runs straight through the scheduler. After
each run the engine is drained and its ID-conservation invariants checked.
``run`` = :meth:`AmuSession.prepare` (build the stack) +
:meth:`AmuSession.execute` (drive it) — benchmarks use the split form to
keep construction out of their timed region.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Union

import time

from dataclasses import dataclass, field, fields

import numpy as np

from repro.amu.config import FREQ_GHZ, AmuConfig
from repro.amu.registry import REGISTRY, Port, WorkloadRegistry
from repro.core.coroutines import SCHEDULER_KINDS
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import make_engine
from repro.core.farmem import FarMemoryModel


@dataclass(frozen=True)
class RunStats:
    """Typed result of one :meth:`AmuSession.run` (plus dict-style reads
    for the pre-session callers that indexed the old stats dict).

    ``regions`` carries per-tier request/byte/MLP stats when the config's
    far memory is heterogeneous (``AmuConfig(far=[...regions...])``), and
    is ``None`` for the flat model.

    ``faults_injected`` / ``retries`` / ``timeouts`` / ``failovers`` /
    ``availability`` report the fault plane: device-side fault draws, the
    retry/failover traffic the scheduler re-issued, and the fraction of
    logical requests that ultimately succeeded. Zero-fault configs keep
    the defaults (all-zero, availability 1.0).

    The ``req_*`` fields carry per-request completion-latency percentiles
    (µs) for request-level ports — those whose instance fills
    ``request_latency_cycles`` (the serving workload); ``None`` elsewhere.

    ``engine_entries`` / ``rows_per_entry`` / ``us_per_entry`` are host-side
    observability counters (how many times the run crossed the Python-level
    AMI surface, how many request rows the average crossing carried, and
    wall-clock µs of driver time per crossing). The first two are
    deterministic model facts; ``us_per_entry`` is wall-clock and excluded
    from equality comparisons.
    """
    cycles: float
    insts: float
    ipc: float
    mlp: float
    requests: int
    bytes: int
    disamb_cycles: float
    disamb_frac: float
    us: float
    units: int
    vector: bool
    verified: Optional[bool]
    workload: str = ""
    regions: Optional[Dict[str, Dict[str, float]]] = None
    faults_injected: int = 0
    retries: int = 0
    timeouts: int = 0
    failovers: int = 0
    availability: float = 1.0
    req_count: Optional[int] = None
    req_mean_us: Optional[float] = None
    req_p50_us: Optional[float] = None
    req_p99_us: Optional[float] = None
    req_p999_us: Optional[float] = None
    engine_entries: Optional[int] = None
    rows_per_entry: Optional[float] = None
    us_per_entry: Optional[float] = field(default=None, compare=False)

    # mapping-style access keeps old dict-consumer code working unchanged;
    # only COMPARABLE field names are keys (method names like "keys" stay
    # invisible, exactly as on the old plain dict, and wall-clock fields
    # stay out so to_dict() equality remains a model-identity check —
    # matching dataclass __eq__, which honors compare=False)
    def __getitem__(self, key: str):
        if key in self.keys():
            return getattr(self, key)
        raise KeyError(key)

    def __contains__(self, key: str) -> bool:
        return key in self.keys()

    def __iter__(self) -> Iterator[str]:
        return iter(self.keys())

    def keys(self):
        return [f.name for f in fields(self) if f.compare]

    def get(self, key: str, default=None):
        return getattr(self, key) if key in self.keys() else default

    def to_dict(self) -> Dict[str, object]:
        return {f.name: getattr(self, f.name) for f in fields(self)
                if f.compare}


def _request_latency_fields(lat_cycles) -> Dict[str, object]:
    """RunStats ``req_*`` kwargs from an instance's per-request completion
    latencies (cycles; negative entries mean the request never completed
    and are excluded). Empty dict of Nones when the port is not
    request-level."""
    none = dict(req_count=None, req_mean_us=None, req_p50_us=None,
                req_p99_us=None, req_p999_us=None)
    if lat_cycles is None:
        return none
    lat = np.asarray(lat_cycles, dtype=float)
    lat = lat[lat >= 0.0]
    if lat.size == 0:
        return none
    us = lat / (FREQ_GHZ * 1e3)
    p50, p99, p999 = np.quantile(us, [0.5, 0.99, 0.999])
    return dict(req_count=int(lat.size), req_mean_us=float(us.mean()),
                req_p50_us=float(p50), req_p99_us=float(p99),
                req_p999_us=float(p999))


class AmuSession:
    """Context manager owning one AMU execution stack.

    Holds the :class:`AmuConfig`; each :meth:`run` builds the far-memory
    model, engine, cost model, disambiguator and scheduler from it, runs the
    port to completion, drains + invariant-checks the engine, and leaves
    ``engine`` / ``far`` / ``scheduler`` / ``instance`` on the session for
    inspection (traces, SPM bytes, far-memory contents).
    """

    def __init__(self, config: AmuConfig = AmuConfig(),
                 registry: WorkloadRegistry = REGISTRY):
        self.config = config
        self.registry = registry
        self.engine = None
        self.far: Optional[FarMemoryModel] = None
        self.scheduler = None
        self.instance: Optional[Port] = None
        self._use_vector = False

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "AmuSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Drop the execution stack (runs already drain + check the engine;
        closing only releases the references)."""
        self.engine = self.far = self.scheduler = self.instance = None

    # ----------------------------------------------------------------- run
    def _build(self, port: Union[str, Port], **build_kw) -> Port:
        if not isinstance(port, str):
            return port
        cfg = self.config
        return self.registry.build(port, cfg.seed, vector=cfg.vector,
                                   llvm_mode=cfg.llvm_mode,
                                   pipeline_k=cfg.pipeline_k, **build_kw)

    def prepare(self, port: Union[str, Port], *,
                record_trace: bool = False, **build_kw) -> Port:
        """Build the execution stack for `port` without running it: far
        memory, engine, disambiguator, scheduler — all from the config.
        Callers that time the run (benchmarks) call this first, then
        :meth:`execute`; :meth:`run` is the two fused."""
        cfg = self.config
        inst = self._build(port, **build_kw)
        # which port actually runs: registry builds are stamped; raw
        # prebuilt ports without the stamp fall back to the config's intent
        self._use_vector = bool(getattr(inst, "vector", cfg.vector))
        ecfg = cfg.resolve_engine_config(inst.engine_config)
        far = FarMemoryModel(
            cfg.resolve_far_config(), host_jit=cfg.host_jit,
            timeout_cycles=cfg.retry.timeout_cycles if cfg.retry else 0.0)
        eng = make_engine(cfg.engine, ecfg, far, inst.mem,
                          record_trace=record_trace)
        disamb = CuckooAddressSet() if inst.disambiguation else None
        sched = SCHEDULER_KINDS[cfg.scheduler_kind](
            eng, cost=cfg.cost_model(), disambiguator=disamb,
            dma_mode=cfg.dma_mode, retry=cfg.retry)
        self.engine, self.far, self.scheduler, self.instance = \
            eng, far, sched, inst
        return inst

    def execute(self) -> RunStats:
        """Run the :meth:`prepare`-d port to completion, drain the engine,
        check ID-conservation invariants, and return the stats."""
        cfg = self.config
        inst, eng, sched = self.instance, self.engine, self.scheduler
        if inst is None:
            raise RuntimeError("no port prepared; call prepare() first")
        entries0, rows0 = eng.host_entries, eng.host_rows
        wall0 = time.perf_counter()
        if hasattr(inst, "make_round_tasks"):        # frontier parallelism
            frontier = [inst.root]                   # type: ignore[union-attr]
            while frontier:
                sched.run(inst.make_round_tasks(frontier))  # type: ignore
                frontier = sorted(inst.next_frontier)       # type: ignore
        else:
            sched.run(inst.tasks)
        wall_us = (time.perf_counter() - wall0) * 1e6
        entries = eng.host_entries - entries0
        rows = eng.host_rows - rows0
        eng.drain()
        eng.check_invariants()
        stats = sched.summary()
        req = _request_latency_fields(
            getattr(inst, "request_latency_cycles", None))
        return RunStats(
            cycles=stats["cycles"], insts=stats["insts"], ipc=stats["ipc"],
            mlp=stats["mlp"], requests=stats["requests"],
            bytes=stats["bytes"], disamb_cycles=stats["disamb_cycles"],
            disamb_frac=stats["disamb_frac"],
            us=stats["cycles"] / (FREQ_GHZ * 1e3),
            units=inst.units, vector=self._use_vector,
            verified=bool(inst.verify(eng.mem)) if cfg.verify else None,
            workload=inst.name,
            regions=self.far.region_stats(stats["cycles"]),
            faults_injected=stats.get("faults_injected", 0),
            retries=stats.get("retries", 0),
            timeouts=stats.get("timeouts", 0),
            failovers=stats.get("failovers", 0),
            availability=stats.get("availability", 1.0),
            engine_entries=entries,
            rows_per_entry=rows / entries if entries else 0.0,
            us_per_entry=wall_us / entries if entries else 0.0, **req)

    def run(self, port: Union[str, Port], *,
            record_trace: bool = False, **build_kw) -> RunStats:
        """Run `port` (a registered name, or a prebuilt Port) to completion.

        ``build_kw`` reaches the builder for name lookups (sizes and other
        workload knobs); ``record_trace=True`` keeps the engine's
        issue/fin trace for differential comparisons.
        """
        self.prepare(port, record_trace=record_trace, **build_kw)
        return self.execute()
