"""`AmuSession` — one object owning engine + scheduler + far-memory
lifecycle, with ``session.run(port) -> RunStats``.

The session replaces the ad-hoc build-engine-build-scheduler-run-drain
choreography that used to be copy-pasted between ``run_amu``, the benchmark
drivers and the test suites::

    from repro.amu import AmuConfig, AmuSession

    with AmuSession(AmuConfig(engine="batched", vector=True)) as s:
        stats = s.run("GUPS")            # registered workload by name
        assert stats.verified
        mem = s.engine.mem               # engine/far/instance stay inspectable

``run`` accepts a registered workload name or any prebuilt
:class:`~repro.amu.registry.Port` (e.g. a ``WorkloadInstance`` built with
custom knobs). Frontier-parallel ports (BFS) are driven level-
synchronously; everything else runs straight through the scheduler. After
each run the engine is drained and its ID-conservation invariants checked.
``run`` = :meth:`AmuSession.prepare` (build the stack) +
:meth:`AmuSession.execute` (drive it) — benchmarks use the split form to
keep construction out of their timed region.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import time

from dataclasses import dataclass, field, fields

import numpy as np

from repro.amu.config import FREQ_GHZ, AmuConfig
from repro.amu.registry import REGISTRY, Port, WorkloadRegistry
from repro.core.coroutines import SCHEDULER_KINDS
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import make_engine
from repro.core.farmem import FarMemoryModel
from repro.core.rack import RackArbiter


@dataclass(frozen=True)
class RunStats:
    """Typed result of one :meth:`AmuSession.run` (plus dict-style reads
    for the pre-session callers that indexed the old stats dict).

    ``regions`` carries per-tier request/byte/MLP stats when the config's
    far memory is heterogeneous (``AmuConfig(far=[...regions...])``), and
    is ``None`` for the flat model.

    ``faults_injected`` / ``retries`` / ``timeouts`` / ``failovers`` /
    ``availability`` report the fault plane: device-side fault draws, the
    retry/failover traffic the scheduler re-issued, and the fraction of
    logical requests that ultimately succeeded. Zero-fault configs keep
    the defaults (all-zero, availability 1.0).

    The ``req_*`` fields carry per-request completion-latency percentiles
    (µs) for request-level ports — those whose instance fills
    ``request_latency_cycles`` (the serving workload); ``None`` elsewhere.

    ``engine_entries`` / ``rows_per_entry`` / ``us_per_entry`` are host-side
    observability counters (how many times the run crossed the Python-level
    AMI surface, how many request rows the average crossing carried, and
    wall-clock µs of driver time per crossing). The first two are
    deterministic model facts; ``us_per_entry`` is wall-clock and excluded
    from equality comparisons.
    """
    cycles: float
    insts: float
    ipc: float
    mlp: float
    requests: int
    bytes: int
    disamb_cycles: float
    disamb_frac: float
    us: float
    units: int
    vector: bool
    verified: Optional[bool]
    workload: str = ""
    regions: Optional[Dict[str, Dict[str, float]]] = None
    faults_injected: int = 0
    retries: int = 0
    timeouts: int = 0
    failovers: int = 0
    availability: float = 1.0
    req_count: Optional[int] = None
    req_mean_us: Optional[float] = None
    req_p50_us: Optional[float] = None
    req_p99_us: Optional[float] = None
    req_p999_us: Optional[float] = None
    engine_entries: Optional[int] = None
    rows_per_entry: Optional[float] = None
    us_per_entry: Optional[float] = field(default=None, compare=False)

    # mapping-style access keeps old dict-consumer code working unchanged;
    # only COMPARABLE field names are keys (method names like "keys" stay
    # invisible, exactly as on the old plain dict, and wall-clock fields
    # stay out so to_dict() equality remains a model-identity check —
    # matching dataclass __eq__, which honors compare=False)
    def __getitem__(self, key: str):
        if key in self.keys():
            return getattr(self, key)
        raise KeyError(key)

    def __contains__(self, key: str) -> bool:
        return key in self.keys()

    def __iter__(self) -> Iterator[str]:
        return iter(self.keys())

    def keys(self):
        return [f.name for f in fields(self) if f.compare]

    def get(self, key: str, default=None):
        return getattr(self, key) if key in self.keys() else default

    def to_dict(self) -> Dict[str, object]:
        return {f.name: getattr(self, f.name) for f in fields(self)
                if f.compare}


def _request_latency_fields(lat_cycles) -> Dict[str, object]:
    """RunStats ``req_*`` kwargs from an instance's per-request completion
    latencies (cycles; negative entries mean the request never completed
    and are excluded). Empty dict of Nones when the port is not
    request-level."""
    none = dict(req_count=None, req_mean_us=None, req_p50_us=None,
                req_p99_us=None, req_p999_us=None)
    if lat_cycles is None:
        return none
    lat = np.asarray(lat_cycles, dtype=float)
    lat = lat[lat >= 0.0]
    if lat.size == 0:
        return none
    us = lat / (FREQ_GHZ * 1e3)
    p50, p99, p999 = np.quantile(us, [0.5, 0.99, 0.999])
    return dict(req_count=int(lat.size), req_mean_us=float(us.mean()),
                req_p50_us=float(p50), req_p99_us=float(p99),
                req_p999_us=float(p999))


def _stats_from_summary(stats: Dict[str, object], cfg: AmuConfig, inst: Port,
                        eng, use_vector: bool, regions,
                        entries: int, rows: int,
                        wall_us: float) -> RunStats:
    """Build a :class:`RunStats` from a scheduler ``summary()`` dict (the
    shared tail of :meth:`AmuSession.execute`, reused per rack core —
    callers that attribute shared-device counters per core patch the dict
    before handing it over)."""
    req = _request_latency_fields(
        getattr(inst, "request_latency_cycles", None))
    return RunStats(
        cycles=stats["cycles"], insts=stats["insts"], ipc=stats["ipc"],
        mlp=stats["mlp"], requests=stats["requests"],
        bytes=stats["bytes"], disamb_cycles=stats["disamb_cycles"],
        disamb_frac=stats["disamb_frac"],
        us=stats["cycles"] / (FREQ_GHZ * 1e3),
        units=inst.units, vector=use_vector,
        verified=bool(inst.verify(eng.mem)) if cfg.verify else None,
        workload=inst.name,
        regions=regions,
        faults_injected=stats.get("faults_injected", 0),
        retries=stats.get("retries", 0),
        timeouts=stats.get("timeouts", 0),
        failovers=stats.get("failovers", 0),
        availability=stats.get("availability", 1.0),
        engine_entries=entries,
        rows_per_entry=rows / entries if entries else 0.0,
        us_per_entry=wall_us / entries if entries else 0.0, **req)


class AmuSession:
    """Context manager owning one AMU execution stack.

    Holds the :class:`AmuConfig`; each :meth:`run` builds the far-memory
    model, engine, cost model, disambiguator and scheduler from it, runs the
    port to completion, drains + invariant-checks the engine, and leaves
    ``engine`` / ``far`` / ``scheduler`` / ``instance`` on the session for
    inspection (traces, SPM bytes, far-memory contents).
    """

    def __init__(self, config: AmuConfig = AmuConfig(),
                 registry: WorkloadRegistry = REGISTRY):
        self.config = config
        self.registry = registry
        self.engine = None
        self.far: Optional[FarMemoryModel] = None
        self.scheduler = None
        self.instance: Optional[Port] = None
        self.sanitizer = None
        self._use_vector = False

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "AmuSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Drop the execution stack (runs already drain + check the engine;
        closing only releases the references)."""
        self.engine = self.far = self.scheduler = self.instance = None
        self.sanitizer = None

    # ----------------------------------------------------------------- run
    def _build(self, port: Union[str, Port], **build_kw) -> Port:
        if not isinstance(port, str):
            return port
        cfg = self.config
        return self.registry.build(port, cfg.seed, vector=cfg.vector,
                                   llvm_mode=cfg.llvm_mode,
                                   pipeline_k=cfg.pipeline_k, **build_kw)

    def prepare(self, port: Union[str, Port], *,
                record_trace: bool = False, **build_kw) -> Port:
        """Build the execution stack for `port` without running it: far
        memory, engine, disambiguator, scheduler — all from the config.
        Callers that time the run (benchmarks) call this first, then
        :meth:`execute`; :meth:`run` is the two fused."""
        cfg = self.config
        inst = self._build(port, **build_kw)
        # which port actually runs: registry builds are stamped; raw
        # prebuilt ports without the stamp fall back to the config's intent
        self._use_vector = bool(getattr(inst, "vector", cfg.vector))
        ecfg = cfg.resolve_engine_config(inst.engine_config)
        far = FarMemoryModel(
            cfg.resolve_far_config(), host_jit=cfg.host_jit,
            timeout_cycles=cfg.retry.timeout_cycles if cfg.retry else 0.0)
        eng = make_engine(cfg.engine, ecfg, far, inst.mem,
                          record_trace=record_trace)
        disamb = CuckooAddressSet() if inst.disambiguation else None
        sched = SCHEDULER_KINDS[cfg.scheduler_kind](
            eng, cost=cfg.cost_model(), disambiguator=disamb,
            dma_mode=cfg.dma_mode, retry=cfg.retry)
        eng.port_name = getattr(inst, "name", "")
        self.sanitizer = None
        if cfg.sanitize:
            from repro.analysis.sanitizer import AmiSanitizer
            self.sanitizer = AmiSanitizer(port=eng.port_name)
            self.sanitizer.attach(eng, sched)
        self.engine, self.far, self.scheduler, self.instance = \
            eng, far, sched, inst
        return inst

    def execute(self) -> RunStats:
        """Run the :meth:`prepare`-d port to completion, drain the engine,
        check ID-conservation invariants, and return the stats."""
        cfg = self.config
        inst, eng, sched = self.instance, self.engine, self.scheduler
        if inst is None:
            raise RuntimeError("no port prepared; call prepare() first")
        entries0, rows0 = eng.host_entries, eng.host_rows
        wall0 = time.perf_counter()
        if hasattr(inst, "make_round_tasks"):        # frontier parallelism
            frontier = [inst.root]                   # type: ignore[union-attr]
            while frontier:
                sched.run(inst.make_round_tasks(frontier))  # type: ignore
                frontier = sorted(inst.next_frontier)       # type: ignore
        else:
            sched.run(inst.tasks)
        wall_us = (time.perf_counter() - wall0) * 1e6
        entries = eng.host_entries - entries0
        rows = eng.host_rows - rows0
        eng.drain()
        eng.check_invariants()
        if self.sanitizer is not None:
            self.sanitizer.finish()      # leaked-token / held-lock report
        stats = sched.summary()
        return _stats_from_summary(
            stats, cfg, inst, eng, self._use_vector,
            self.far.region_stats(stats["cycles"]), entries, rows, wall_us)

    def run(self, port: Union[str, Port], *,
            record_trace: bool = False, **build_kw) -> RunStats:
        """Run `port` (a registered name, or a prebuilt Port) to completion.

        ``build_kw`` reaches the builder for name lookups (sizes and other
        workload knobs); ``record_trace=True`` keeps the engine's
        issue/fin trace for differential comparisons.
        """
        self.prepare(port, record_trace=record_trace, **build_kw)
        return self.execute()


# ========================================================================
# Rack-scale sessions: N cores, one shared far memory
# ========================================================================
def _core_seeds(seed: int, cores: int) -> List[int]:
    """Per-core build seeds: core 0 keeps the config seed verbatim (the
    ``cores=1`` bit-identity guarantee) and core i > 0 gets an
    independently spawned child of ``SeedSequence(seed)`` — statistically
    independent streams, deterministic per (seed, cores)."""
    if cores == 1:
        return [seed]
    children = np.random.SeedSequence(seed).spawn(cores - 1)
    return [seed] + [int(c.generate_state(1, np.uint64)[0])
                     for c in children]


def _jain_fairness(xs: Sequence[float]) -> float:
    """Jain's fairness index (Σx)² / (N·Σx²) ∈ (0, 1]; 1.0 = all equal."""
    sq = sum(x * x for x in xs)
    if sq <= 0.0:
        return 1.0
    s = sum(xs)
    return (s * s) / (len(xs) * sq)


@dataclass(frozen=True)
class RackStats:
    """Result of one :meth:`RackSession.run`: the per-core dimension plus
    rack-level aggregates.

    ``cores`` holds one :class:`RunStats` per core. With ``cores=1`` the
    single entry is bit-identical to the plain :class:`AmuSession` result;
    with N > 1 each core's ``requests``/``bytes``/fault counters are the
    arbiter-attributed share of the shared device's global counters, its
    ``mlp`` is 0.0 (in-flight overlap on a shared device has no exact
    per-core split — ``RackStats.mlp`` carries the true device MLP), and
    ``regions`` is ``None`` (the shared per-tier split lives on
    ``RackStats.regions``).

    ``core_gups`` is per-core throughput in giga-units/sec (logical work
    units per nanosecond — true GUPS when the port is GUPS);
    ``aggregate_gups`` divides total units by the rack **makespan** (the
    slowest core), so it only scales with cores while the shared links
    have headroom. ``fairness`` is Jain's index over ``core_gups`` and
    ``link_occupancy`` maps each far-memory link to its serialized-cycle
    total, busy fraction of the makespan, and per-core split.
    """
    cores: Tuple[RunStats, ...]
    cycles: float                       # makespan, cycles
    us: float
    requests: int
    bytes: int
    mlp: float                          # shared-device MLP over the makespan
    core_gups: Tuple[float, ...]
    aggregate_gups: float
    fairness: float
    link_occupancy: Dict[str, Dict[str, object]]
    regions: Optional[Dict[str, Dict[str, float]]]
    verified: Optional[bool]

    @property
    def n_cores(self) -> int:
        return len(self.cores)


class RackSession:
    """Context manager owning a rack of AMU execution stacks.

    ``run(ports)`` builds N per-core engine+SPM+scheduler stacks over ONE
    shared far-memory model and drives them through the deterministic
    global-clock arbiter (:class:`repro.core.rack.RackArbiter` — ties
    break by core index). ``ports`` is a single registered name / prebuilt
    port (homogeneous rack: every core runs it, core i built with its own
    spawned seed) or a sequence of ``config.cores`` of them (colocation
    scenarios, e.g. GUPS next to ``paged_kv_serve``). Frontier-parallel
    ports (BFS) need a per-level outer driver and are not rack-schedulable.

    After the run each engine is drained and invariant-checked; the
    per-core stacks stay inspectable on ``engines`` / ``schedulers`` /
    ``instances`` (and the shared model on ``far``).
    """

    def __init__(self, config: AmuConfig = AmuConfig(),
                 registry: WorkloadRegistry = REGISTRY):
        self.config = config
        self.registry = registry
        self.far: Optional[FarMemoryModel] = None
        self.engines: List = []
        self.schedulers: List = []
        self.instances: List[Port] = []
        self.sanitizers: List = []
        self._use_vector: List[bool] = []

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "RackSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        self.far = None
        self.engines, self.schedulers, self.instances = [], [], []
        self._use_vector = []

    # ----------------------------------------------------------------- run
    def prepare(self, ports: Union[str, Port, Sequence], *,
                record_trace: bool = False, **build_kw) -> List[Port]:
        """Build all per-core stacks without running them: one shared far
        model, then per core a workload instance (spawned seed), engine,
        disambiguator and scheduler."""
        cfg = self.config
        n = cfg.cores
        if isinstance(ports, str) or not isinstance(ports, Sequence):
            if n > 1 and not isinstance(ports, str):
                # one prebuilt instance can't back N cores: its tasks and
                # memory image are single-use state
                raise ValueError(
                    "a homogeneous rack takes a registered workload NAME "
                    "(each core rebuilds with its own spawned seed); for "
                    "prebuilt ports pass one per core")
            port_list = [ports] * n
        else:
            port_list = list(ports)
            if len(port_list) != n:
                raise ValueError(
                    f"got {len(port_list)} ports for cores={n}; pass one "
                    f"port (homogeneous rack) or exactly one per core")
        seeds = _core_seeds(cfg.seed, n)
        far = FarMemoryModel(
            cfg.resolve_far_config(), host_jit=cfg.host_jit,
            timeout_cycles=cfg.retry.timeout_cycles if cfg.retry else 0.0)
        self.far = far
        self.engines, self.schedulers, self.instances = [], [], []
        self.sanitizers = []
        self._use_vector = []
        for i, port in enumerate(port_list):
            if isinstance(port, str):
                inst = self.registry.build(
                    port, seeds[i], vector=cfg.vector,
                    llvm_mode=cfg.llvm_mode, pipeline_k=cfg.pipeline_k,
                    **build_kw)
            else:
                inst = port
            if hasattr(inst, "make_round_tasks"):
                raise NotImplementedError(
                    f"frontier-parallel port {inst.name!r} needs a "
                    f"per-level outer driver; not rack-schedulable")
            self._use_vector.append(bool(getattr(inst, "vector",
                                                 cfg.vector)))
            ecfg = cfg.resolve_engine_config(inst.engine_config)
            eng = make_engine(cfg.engine, ecfg, far, inst.mem,
                              record_trace=record_trace, label=f"core{i}")
            disamb = CuckooAddressSet() if inst.disambiguation else None
            sched = SCHEDULER_KINDS[cfg.scheduler_kind](
                eng, cost=cfg.cost_model(), disambiguator=disamb,
                dma_mode=cfg.dma_mode, retry=cfg.retry)
            eng.port_name = getattr(inst, "name", "")
            if cfg.sanitize:
                from repro.analysis.sanitizer import AmiSanitizer
                san = AmiSanitizer(port=eng.port_name, label=f"core{i}")
                san.attach(eng, sched)
                self.sanitizers.append(san)
            self.engines.append(eng)
            self.schedulers.append(sched)
            self.instances.append(inst)
        return self.instances

    def execute(self) -> RackStats:
        """Arbitrate the :meth:`prepare`-d cores to completion, drain and
        invariant-check every engine, and return the rack stats."""
        cfg = self.config
        if not self.instances:
            raise RuntimeError("no ports prepared; call prepare() first")
        n = len(self.instances)
        arb = RackArbiter(self.far, self.schedulers)
        for sched, inst in zip(self.schedulers, self.instances):
            for task in inst.tasks:
                sched.spawn(task)
        arb.run()
        per_core: List[RunStats] = []
        for i in range(n):
            eng, sched, inst = self.engines[i], self.schedulers[i], \
                self.instances[i]
            eng.drain()
            eng.check_invariants()
            if self.sanitizers:
                self.sanitizers[i].finish()
            stats = dict(sched.summary())
            if n == 1:
                regions = self.far.region_stats(stats["cycles"])
            else:
                # shared-device counters: replace the global reads with
                # the arbiter's per-core attribution (regions/MLP stay
                # rack-level — see RackStats)
                regions = None
                stats["requests"] = arb.requests[i]
                stats["bytes"] = arb.bytes_moved[i]
                stats["mlp"] = 0.0
                if "faults_injected" in stats:
                    stats["faults_injected"] = arb.errors[i] \
                        + arb.timeouts[i]
                    stats["timeouts"] = arb.timeouts[i]
                    logical = (arb.requests[i] - stats["retries"]
                               - stats["failovers"])
                    stats["availability"] = \
                        1.0 - stats["failed"] / max(logical, 1)
            per_core.append(_stats_from_summary(
                stats, cfg, inst, eng, self._use_vector[i], regions,
                eng.host_entries, eng.host_rows, arb.wall_us[i]))
        makespan = arb.makespan
        us = makespan / (FREQ_GHZ * 1e3)
        core_gups = tuple(
            (s.units / s.us) * 1e-3 if s.us > 0 else 0.0 for s in per_core)
        total_units = sum(s.units for s in per_core)
        verified: Optional[bool] = None
        if cfg.verify:
            verified = all(bool(s.verified) for s in per_core)
        return RackStats(
            cores=tuple(per_core),
            cycles=makespan,
            us=us,
            requests=self.far.requests,
            bytes=self.far.bytes_moved,
            mlp=self.far.avg_mlp(makespan),
            core_gups=core_gups,
            aggregate_gups=(total_units / us) * 1e-3 if us > 0 else 0.0,
            fairness=_jain_fairness(core_gups),
            link_occupancy=self.far.link_occupancy(makespan),
            regions=self.far.region_stats(makespan),
            verified=verified)

    def run(self, ports: Union[str, Port, Sequence], *,
            record_trace: bool = False, **build_kw) -> RackStats:
        """Run `ports` across the rack to completion (prepare + execute)."""
        self.prepare(ports, record_trace=record_trace, **build_kw)
        return self.execute()
