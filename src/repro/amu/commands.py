"""Typed per-coroutine command facade: ``ctx.aload(...)`` instead of raw
command-object construction.

A port body is a generator that yields AMI commands; the facade is the one
place that knows which concrete command class each operation lowers to, so
port authors write::

    data = yield ctx.spm_read(slot, 8)          # read-only view, zero-copy
    yield ctx.aload(slot, addr, 8)              # issue + await (one hop)
    tok = yield ctx.aload(slot, addr, 8, wait=False)   # issue, keep running
    yield ctx.await_rid(tok)
    yield ctx.aload_vec(slots, addrs, 8)        # whole vector, one hop
    yield ctx.acquire_vec(locks)                # whole lock set, one hop

instead of hand-picking between ``Aload``/``AloadNoWait``/``AloadVec`` and
friends. Every method returns the command object ("handle") to yield; the
lowering is 1:1, so facade-written ports stay trace-identical to ports that
construct the command dataclasses directly.
"""
from __future__ import annotations

from typing import Optional

from repro.core.coroutines import (Acquire, AcquireVec, Aload, AloadNoWait,
                                   AloadVec, Astore, AstoreNoWait, AstoreVec,
                                   AwaitRid, AwaitRids, Cost, Now, Release,
                                   ReleaseVec, SpmRead, SpmWrite, WaitUntil)


class CommandFacade:
    """Stateless constructor facade over the AMI command set (§5.2)."""

    # -------------------------------------------------- asynchronous moves
    @staticmethod
    def aload(spm: int, mem: int, size: Optional[int] = None, *,
              wait: bool = True):
        """Far memory -> SPM. ``wait=True`` suspends until completion;
        ``wait=False`` resumes immediately with a wait token (pair with
        :meth:`await_rid`).

        Under fault injection (a region with a :class:`FaultModel`), a
        ``wait=True`` yield resumes with the request's final AMART status
        (``STATUS_OK`` / ``STATUS_ERROR`` / ``STATUS_TIMED_OUT`` — after
        any scheduler retries/failover); failed requests move no data.
        Zero-fault configs resume with ``None`` exactly as before."""
        return Aload(spm, mem, size) if wait else AloadNoWait(spm, mem, size)

    @staticmethod
    def astore(spm: int, mem: int, size: Optional[int] = None, *,
               wait: bool = True):
        """SPM -> far memory; see :meth:`aload` for ``wait``."""
        return Astore(spm, mem, size) if wait else AstoreNoWait(spm, mem, size)

    @staticmethod
    def aload_vec(spm, mem, size: Optional[int] = None, *,
                  wait: bool = True):
        """One AMI vector command for ``len(spm)`` loads (§4.2 metadata
        batching). ``wait=True`` fuses the await (one generator hop per
        vector); ``wait=False`` returns wait tokens for :meth:`await_rids`.

        Under fault injection a fused-await yield resumes with a per-lane
        ``int8`` status array (lane-aligned with ``spm``); zero-fault
        configs resume with ``None``."""
        return AloadVec(spm, mem, size, wait)

    @staticmethod
    def astore_vec(spm, mem, size: Optional[int] = None, *,
                   wait: bool = True):
        """Vectorized astore; see :meth:`aload_vec`."""
        return AstoreVec(spm, mem, size, wait)

    @staticmethod
    def await_rid(tok):
        """Suspend until the token from a ``wait=False`` issue completes.
        Under fault injection the yield resumes with that request's final
        status int (``None`` on zero-fault configs)."""
        return AwaitRid(tok)

    @staticmethod
    def await_rids(toks):
        """Suspend until EVERY token completes (one coroutine resume).
        Under fault injection the yield resumes with a per-token ``int8``
        status array (``None`` on zero-fault configs)."""
        return AwaitRids(tuple(toks) if not hasattr(toks, "dtype") else toks)

    # ------------------------------------------------ software lock plane
    @staticmethod
    def acquire(addr: int):
        """start_access on `addr`'s 64B block (Listing 1)."""
        return Acquire(addr)

    @staticmethod
    def release(addr: int):
        """end_access; FIFO hand-off to the head waiter."""
        return Release(addr)

    @staticmethod
    def acquire_vec(addrs):
        """Acquire a whole ascending block-deduped lock set in one hop
        (see ``workloads._lock_set`` for how to produce one)."""
        return AcquireVec(addrs)

    @staticmethod
    def release_vec(addrs):
        """Release a whole lock set (FIFO hand-offs included) in one hop."""
        return ReleaseVec(addrs)

    # --------------------------------------------------- synchronous SPM
    @staticmethod
    def spm_read(spm: int, size: int):
        """Read-only numpy view aliasing live SPM (zero-copy contract)."""
        return SpmRead(spm, size)

    @staticmethod
    def spm_write(spm: int, data):
        """Register->SPM store; `data` is bytes or a C-contiguous ndarray."""
        return SpmWrite(spm, data)

    # ------------------------------------------------------------- compute
    @staticmethod
    def cost(insts: float = 0.0, cycles: float = 0.0):
        """Charge plain compute between memory ops."""
        return Cost(insts, cycles)

    # ------------------------------------------------------------ the clock
    @staticmethod
    def wait_until(cycles: float):
        """Suspend until the core clock reaches the ABSOLUTE time `cycles`
        (continues immediately if it is already past — open-loop arrival)."""
        return WaitUntil(cycles)

    @staticmethod
    def now():
        """Resume with the current core clock in cycles (free: a
        cycle-counter read) — timestamp request completions with it."""
        return Now()


#: Singleton facade — ports do ``from repro.amu import ctx``.
ctx = CommandFacade()
