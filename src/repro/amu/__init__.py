"""Unified AMU session API — the paper's programming framework as one
coherent public surface.

Three pieces (see TESTING.md for the migration table from the old knobs):

* :class:`AmuConfig` — one frozen config object (engine kind, scheduler
  kind, vector/pipeline-K, DMA mode, SPM budget, far-memory operating
  point) with validation and ``derive``-style variation.
* :class:`AmuSession` — a context manager owning engine + scheduler +
  far-memory lifecycle; ``session.run(port) -> RunStats``.
  :class:`RackSession` is its rack-scale sibling: ``AmuConfig(cores=N)``
  runs N per-core stacks over one shared far memory
  (``run(ports) -> RackStats``).
* :func:`workload` / :data:`REGISTRY` — the pluggable workload registry
  (one decorated builder per scenario, with declared capabilities), plus
  the :class:`Port` protocol any custom workload can satisfy.

Port bodies use the typed command facade :data:`ctx`
(``yield ctx.aload(...)`` etc.) instead of hand-rolling command objects.
"""
from repro.amu.commands import CommandFacade, ctx
from repro.analysis.sanitizer import AmiProtocolError
from repro.amu.config import (FREQ_GHZ, LINE, AmuConfig, RetryPolicy,
                              far_config, far_region)
from repro.amu.registry import (REGISTRY, Port, WorkloadDef,
                                WorkloadRegistry, workload)
from repro.amu.session import AmuSession, RackSession, RackStats, RunStats
from repro.core.farmem import (STATUS_ERROR, STATUS_OK, STATUS_TIMED_OUT,
                               BimodalTail, FarMemoryConfig, FarMemoryRegion,
                               FaultModel, LatencyDistribution, LinkFlap,
                               LognormalLatency, UniformJitter)

# Populate REGISTRY with the built-in Table 3 workloads. Deliberately last:
# the port module imports the facade/registry submodules above, which are
# fully initialized by now even when the import chain started from
# `repro.core.workloads` itself.
import repro.core.workloads  # noqa: E402,F401  (registration side-effect)
import repro.core.serving    # noqa: E402,F401  (registers paged_kv_serve)

__all__ = [
    "AmuConfig", "AmuSession", "RunStats", "RackSession", "RackStats",
    "ctx", "CommandFacade",
    "workload", "Port", "WorkloadDef", "WorkloadRegistry", "REGISTRY",
    "far_config", "far_region", "FREQ_GHZ", "LINE",
    "FarMemoryConfig", "FarMemoryRegion", "LatencyDistribution",
    "UniformJitter", "LognormalLatency", "BimodalTail",
    "FaultModel", "LinkFlap", "RetryPolicy",
    "STATUS_OK", "STATUS_ERROR", "STATUS_TIMED_OUT",
    "AmiProtocolError",
]
