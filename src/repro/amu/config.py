"""`AmuConfig` — one frozen, validated config object for an AMU run.

Replaces the six orthogonal knobs that used to thread positionally through
``run_amu`` / ``sim.run`` / the builders (``engine=``, ``vector=``,
``dma_mode=``, pipeline ``K``, SPM budget, far-memory latency): construct
one config, derive variants with :meth:`AmuConfig.derive`, hand it to
:class:`repro.amu.AmuSession`.

Migration table (old knob -> config field) lives in TESTING.md.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass, replace
from typing import Optional, Sequence, Union

from repro.configs.base import EngineConfig
from repro.core.coroutines import SCHEDULER_KINDS, CostModel
from repro.core.engine import ENGINE_KINDS
from repro.core.farmem import (FarMemoryConfig, FarMemoryRegion, FaultModel,
                               LatencyDistribution)

#: Simulated core clock (Table 2: 3 GHz, 6-wide OoO).
FREQ_GHZ = 3.0
#: Baseline cache-line granularity.
LINE = 64


def _env_sanitize() -> bool:
    """Default for ``AmuConfig.sanitize``: the ``AMU_SANITIZE`` env var,
    so CI can run an entire suite with the sanitizer attached without
    threading the flag through every constructor."""
    return os.environ.get("AMU_SANITIZE", "").lower() not in ("", "0", "false")


def far_config(latency_us: float, bandwidth_gbs: float = 64.0,
               max_inflight: int = 0, **kw) -> FarMemoryConfig:
    """The paper's far-memory operating point at `latency_us` (Fig 1/7).
    Extra keywords reach :class:`FarMemoryConfig` (e.g. ``distribution=``
    for a tail-latency draw). (Transfer granularity is a property of each
    request, not of the device — set it on the :class:`EngineConfig`
    instead.)"""
    return FarMemoryConfig.from_latency_us(
        latency_us, freq_ghz=FREQ_GHZ, bandwidth_gbs=bandwidth_gbs,
        max_inflight=max_inflight, **kw)


def far_region(name: str, start: int, size: int, latency_us: float,
               bandwidth_gbs: float = 64.0, max_inflight: int = 0,
               link: Optional[str] = None,
               distribution: Optional[LatencyDistribution] = None,
               jitter_frac: float = 0.0,
               faults: Optional[FaultModel] = None,
               failover: Optional[str] = None) -> FarMemoryRegion:
    """One tier of a heterogeneous far memory, in the paper's µs / GB/s
    units. Pass a list of these as ``AmuConfig(far=[...])`` to run a
    workload against mixed local-DRAM / fast-CXL / cross-switch tiers;
    regions naming the same ``link`` contend on one shared channel.
    ``faults`` attaches a seeded :class:`FaultModel` (error/drop draws,
    outage windows); ``failover`` names the region that absorbs this one's
    requests after retry exhaustion."""
    return FarMemoryRegion.from_latency_us(
        name, start, size, latency_us, freq_ghz=FREQ_GHZ,
        bandwidth_gbs=bandwidth_gbs, max_inflight=max_inflight, link=link,
        distribution=distribution, jitter_frac=jitter_frac,
        faults=faults, failover=failover)


@dataclass(frozen=True)
class RetryPolicy:
    """Recovery policy for faulted far-memory requests (the AMI side of
    the fault plane). A scheduler given a policy re-issues each failed or
    timed-out request up to ``max_retries`` times with deterministic
    exponential backoff (``backoff * 2**attempt`` core cycles between the
    failure notice and the re-issue); after exhaustion it tries the
    region's configured ``failover`` region once, and only then delivers
    the failure status to the awaiting coroutine. ``timeout_cycles`` > 0
    additionally classifies any request whose modeled completion exceeds
    its issue time by more than that budget as TIMED_OUT at the deadline
    (a client-side timer on top of the device-side fault draws). All
    retry traffic is charged to the far-memory ledger honestly — retries
    are real requests."""

    max_retries: int = 3
    timeout_cycles: float = 0.0
    backoff: float = 256.0

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.timeout_cycles < 0.0:
            raise ValueError(
                f"timeout_cycles must be >= 0, got {self.timeout_cycles}")
        if self.backoff < 0.0:
            raise ValueError(f"backoff must be >= 0, got {self.backoff}")


@dataclass(frozen=True)
class AmuConfig:
    """Everything an AMU execution needs, in one validated object.

    * ``engine`` — timed-engine implementation: ``"scalar"`` (the per-event
      oracle) or ``"batched"`` (vectorized SoA; production sweeps).
    * ``scheduler`` — runtime loop: ``"auto"`` (follow the engine:
      ``"fused"`` on the batched engine, ``"scalar"`` on the oracle),
      ``"scalar"`` (one getfin + one task step per turn), ``"batched"``
      (epoch-stepped ``getfin_all`` drain) or ``"fused"`` (epoch-stepped
      AND epoch-staged: one engine/far entry per epoch; bit-identical to
      ``"batched"`` on the same engine).
    * ``host_jit`` — compile the far model's sequential host loops
      (injection chains, MLP ledger accumulation) with numba when it is
      importable; silently falls back to the pure-numpy paths otherwise.
      Bit-identical either way — this is a host-throughput knob, not a
      model knob.
    * ``vector`` — run the workload's AloadVec/AstoreVec (or software-
      pipelined chase) port where one is registered.
    * ``pipeline_k`` — chases per coroutine for pipelined ports
      (``None`` -> the port's default).
    * ``dma_mode`` — external-engine ablation: ``batch_ids=1`` plus the
      per-request descriptor/doorbell cost.
    * ``llvm_mode`` — compiler-lowered loop cost model (Table 4 AMU-LLVM),
      plus any workload-declared LLVM rebuild kwargs.
    * ``latency_us`` / ``max_inflight`` — far-memory operating point
      (``max_inflight`` models device-side queue backpressure, 0 =
      unlimited); ``far`` replaces both with a fully custom
      :class:`FarMemoryConfig` *or a sequence of*
      :class:`~repro.core.farmem.FarMemoryRegion` (heterogeneous tiers,
      validated and normalized into one config) — setting ``far`` together
      with a non-default latency/backpressure knob is rejected, so a
      sweep's ``derive(latency_us=...)`` can never be silently ignored.
    * ``engine_config`` — overrides the workload's sized
      :class:`EngineConfig` wholesale; ``spm_bytes`` overrides just the
      SPM budget of whichever config is in effect.
    * ``retry`` — :class:`RetryPolicy` for faulted far-memory requests
      (deterministic backoff re-issue, then failover); also arms the far
      model's client-side ``timeout_cycles`` timer. ``None`` (default)
      delivers failure statuses immediately with no retry traffic.
    * ``cores`` — rack width: N complete engine+SPM+scheduler stacks over
      ONE shared far-memory model, interleaved by the deterministic
      global-clock arbiter (``repro.core.rack``). ``cores=1`` (default)
      is bit-identical to the plain single-core session; N > 1 runs go
      through :class:`repro.amu.RackSession`.
    * ``seed`` / ``verify`` — build seed; run the port's numpy oracle at
      the end. In a rack, core 0 builds with ``seed`` verbatim and core
      i > 0 with a child seed spawned from ``SeedSequence(seed)``.
    * ``sanitize`` — attach the AMI protocol sanitizer
      (:class:`repro.analysis.AmiSanitizer`) to every engine+scheduler
      stack of the run (each rack core gets its own): SPM shadow map for
      DMA/SPM races, rid lifecycle leak report at port exit, lock-order
      cycle detection. Pure observation — traces/stats/RNG bitstreams are
      bit-identical with it on or off; violations raise
      :class:`repro.analysis.AmiProtocolError`. Defaults to the
      ``AMU_SANITIZE`` environment variable (unset/0/false -> off), so a
      whole suite can be run sanitized without touching call sites.
    """
    engine: str = "batched"
    scheduler: str = "auto"
    host_jit: bool = False
    vector: bool = False
    pipeline_k: Optional[int] = None
    dma_mode: bool = False
    llvm_mode: bool = False
    latency_us: Optional[float] = None     # None -> 1.0 (unless far= given)
    max_inflight: int = 0
    far: Optional[Union[FarMemoryConfig,
                        Sequence[FarMemoryRegion]]] = None
    engine_config: Optional[EngineConfig] = None
    spm_bytes: Optional[int] = None
    retry: Optional[RetryPolicy] = None
    cores: int = 1
    seed: int = 0
    verify: bool = True
    sanitize: bool = dataclasses.field(default_factory=lambda: _env_sanitize())

    def __post_init__(self) -> None:
        if self.engine not in ENGINE_KINDS:
            raise KeyError(f"unknown engine {self.engine!r}; "
                           f"known: {sorted(ENGINE_KINDS)}")
        if self.scheduler != "auto" and self.scheduler not in SCHEDULER_KINDS:
            raise KeyError(f"unknown scheduler {self.scheduler!r}; "
                           f"known: {sorted(SCHEDULER_KINDS)} or 'auto'")
        if self.pipeline_k is not None and self.pipeline_k < 1:
            raise ValueError(f"pipeline_k must be >= 1, got {self.pipeline_k}")
        if self.far is not None and not isinstance(self.far, FarMemoryConfig):
            # a sequence of regions: validate and normalize into one
            # FarMemoryConfig (FarMemoryConfig.__post_init__ checks range
            # ordering, name uniqueness, per-region knob sanity)
            regions = tuple(self.far)
            if not regions or not all(isinstance(r, FarMemoryRegion)
                                      for r in regions):
                raise TypeError(
                    "far= takes a FarMemoryConfig or a non-empty sequence "
                    f"of FarMemoryRegion, got {self.far!r}")
            # seed stays FarMemoryConfig's default, matching the flat
            # resolve path; a custom far-memory seed is spelled as an
            # explicit FarMemoryConfig(regions=..., seed=...)
            object.__setattr__(self, "far", FarMemoryConfig(regions=regions))
        if self.far is not None and (self.latency_us is not None
                                     or self.max_inflight):
            # an explicit FarMemoryConfig replaces the whole operating
            # point; rejecting the combination means a sweep's
            # derive(latency_us=...) can never be silently discarded
            raise ValueError(
                "far= replaces the whole far-memory model; don't also set "
                "latency_us/max_inflight (derive a new far_config instead)")
        if self.latency_us is not None and not self.latency_us > 0:
            raise ValueError(f"latency_us must be > 0, got {self.latency_us}")
        if self.max_inflight < 0:
            raise ValueError(
                f"max_inflight must be >= 0, got {self.max_inflight}")
        if self.spm_bytes is not None and self.spm_bytes <= 0:
            raise ValueError(f"spm_bytes must be > 0, got {self.spm_bytes}")
        if self.retry is not None and not isinstance(self.retry, RetryPolicy):
            raise TypeError(f"retry= takes a RetryPolicy, got {self.retry!r}")
        if not isinstance(self.cores, int) or isinstance(self.cores, bool) \
                or self.cores < 1:
            raise ValueError(f"cores must be an int >= 1, got {self.cores!r}")
        if self.seed < 0:
            raise ValueError(f"seed must be >= 0, got {self.seed}")

    # ------------------------------------------------------------ derive
    def derive(self, **changes) -> "AmuConfig":
        """``dataclasses.replace`` with re-validation: the one sanctioned
        way to vary a knob (sweeps derive per-latency configs from one
        base instead of re-threading positional arguments)."""
        return replace(self, **changes)

    # ------------------------------------------------- resolved properties
    @property
    def scheduler_kind(self) -> str:
        """The runtime loop actually used. ``"auto"`` follows the engine:
        the batched engine gets the epoch-fused loop (bit-identical to the
        per-command batched loop, one engine entry per epoch), the scalar
        oracle keeps the scalar loop."""
        if self.scheduler != "auto":
            return self.scheduler
        return "fused" if self.engine == "batched" else self.engine

    def resolve_engine_config(self, port_config: EngineConfig) -> EngineConfig:
        """The :class:`EngineConfig` for a run: explicit override, else the
        port's own sizing; then the SPM budget and DMA-mode ablation."""
        ecfg = self.engine_config or port_config
        if self.spm_bytes is not None:
            ecfg = dataclasses.replace(ecfg, spm_bytes=self.spm_bytes)
        if self.dma_mode:
            ecfg = dataclasses.replace(ecfg, batch_ids=1)
        return ecfg

    def resolve_far_config(self) -> FarMemoryConfig:
        if self.far is not None:
            return self.far
        lat = 1.0 if self.latency_us is None else self.latency_us
        return far_config(lat, max_inflight=self.max_inflight)

    def cost_model(self) -> CostModel:
        if not self.llvm_mode:
            return CostModel()
        # compiler-lowered loop: no coroutine frame save/restore, fewer
        # framework instructions per op (Table 4: AMU-LLVM beats hand-ported)
        return replace(CostModel(), switch_insts=20, switch_stall_cycles=55.0,
                       ami_issue_insts=6, getfin_insts=6)
