"""Protocol-correctness tooling for the AMI contract.

Two complementary checkers over the same protocol rules (leaked request
IDs, SPM/DMA races, lock discipline):

* :mod:`repro.analysis.amilint` — static AST + abstract-interpretation
  lint over port generators (``tools/amilint.py`` is the CLI).
* :mod:`repro.analysis.sanitizer` — the ``AmuConfig(sanitize=True)``
  runtime shadow-state checker that wraps any engine/scheduler pair
  (scalar, batched, epoch-fused, every core of a rack) with pure
  observation: bit-identical traces/stats/RNG whether on or off.
"""
from repro.analysis.amilint import (Finding, lint_file, lint_registry,
                                    lint_source)
from repro.analysis.sanitizer import AmiProtocolError, AmiSanitizer

__all__ = [
    "AmiProtocolError", "AmiSanitizer",
    "Finding", "lint_source", "lint_file", "lint_registry",
]
