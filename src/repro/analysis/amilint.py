"""amilint — static AST + abstract-interpretation lint for AMI ports.

A *port generator* is any function whose own body (nested defs excluded)
yields at least one ``ctx.<method>(...)`` facade call or raw command
construction (``Aload(...)`` etc.). For each one, six rule families run:

======  =================================================================
AMI001  leaked request ID: a ``wait=False`` issue whose token is
        discarded, never flows into any ``await_rid``/``await_rids``
        (directly or through a container), or is only awaited on some
        conditional path.
AMI002  SPM race: an ``spm_read``/``spm_write`` whose window may overlap
        the destination of an in-flight ``wait=False`` load (interval
        abstract interpretation over normalized ``base + const`` SPM
        address expressions; awaiting the token clears its window).
AMI003  lock matching: ``Acquire`` without a matching ``Release`` (and
        vice versa), ``acquire_vec`` without the paired ``release_vec``.
AMI004  lock order: constant scalar acquires held simultaneously in
        non-ascending/duplicated order; ``acquire_vec`` over a literal
        list that is not strictly ascending and distinct.
AMI005  non-command yield: a yield whose value cannot be an AMI command
        (bare yield, unknown ``ctx`` method, arbitrary expression).
AMI006  engine bypass: a direct call to an engine-surface method
        (``aload``/``getfin``/``spm_read``/...) on anything but ``ctx``.
======  =================================================================

False positives are suppressed per line with ``# amilint: ignore`` or
``# amilint: ignore[AMI002,AMI005]``.

The pass is deliberately conservative: token flow follows simple
assignments, ``append``/``extend`` and subscript stores; loop bodies are
interpreted once (windows issued in a loop and awaited in a later loop —
the pipelined-port idiom — do not re-trigger across the back edge); a
race is only reported when the normalized base expressions match.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

#: ctx facade methods (cross-checked against CommandFacade in the tests).
FACADE_METHODS = {
    "aload", "astore", "aload_vec", "astore_vec", "await_rid", "await_rids",
    "acquire", "release", "acquire_vec", "release_vec", "spm_read",
    "spm_write", "cost", "wait_until", "now",
}

#: Raw command classes a port may construct instead of the facade.
COMMAND_CLASSES = {
    "Aload", "Astore", "AloadNoWait", "AstoreNoWait", "AloadVec",
    "AstoreVec", "AwaitRid", "AwaitRids", "Acquire", "Release",
    "AcquireVec", "ReleaseVec", "SpmRead", "SpmWrite", "Cost", "WaitUntil",
    "Now",
}

#: Engine-surface methods a port must never call directly (AMI006); the
#: scheduler owns the engine — ports speak only through yielded commands.
ENGINE_SURFACE = {
    "aload", "astore", "aload_batch", "astore_batch", "getfin",
    "getfin_all", "stage_epoch", "flush_epoch", "getfin_epoch",
    "spm_read", "spm_write",
}

_SUPPRESS_RE = re.compile(r"#\s*amilint:\s*ignore(?:\[([A-Z0-9,\s]+)\])?")


@dataclass
class Finding:
    rule: str
    message: str
    file: str
    line: int
    col: int
    func: str

    def __str__(self) -> str:
        return (f"{self.file}:{self.line}:{self.col}: {self.rule} "
                f"{self.message} [in {self.func}]")

    def to_dict(self) -> dict:
        return {"rule": self.rule, "message": self.message,
                "file": self.file, "line": self.line, "col": self.col,
                "func": self.func}


# ========================================================================
# AST helpers
# ========================================================================

def _walk_own(node: ast.AST):
    """Yield descendants of `node`, not descending into nested function
    definitions (each generator is analyzed on its own)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield child
        yield from _walk_own(child)


def _ctx_method(call: ast.AST) -> Optional[str]:
    """``ctx.<m>(...)`` -> ``m``; anything else -> None."""
    if (isinstance(call, ast.Call) and isinstance(call.func, ast.Attribute)
            and isinstance(call.func.value, ast.Name)
            and call.func.value.id == "ctx"):
        return call.func.attr
    return None


def _command_class(call: ast.AST) -> Optional[str]:
    """``Aload(...)`` (or any known command class) -> class name."""
    if (isinstance(call, ast.Call) and isinstance(call.func, ast.Name)
            and call.func.id in COMMAND_CLASSES):
        return call.func.id
    return None


def _arg(call: ast.Call, idx: int, name: str) -> Optional[ast.AST]:
    if idx < len(call.args):
        return call.args[idx]
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _const_int(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.Add,
                                                            ast.Sub,
                                                            ast.Mult)):
        lo = _const_int(node.left)
        ro = _const_int(node.right)
        if lo is not None and ro is not None:
            if isinstance(node.op, ast.Add):
                return lo + ro
            if isinstance(node.op, ast.Sub):
                return lo - ro
            return lo * ro
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _const_int(node.operand)
        return -v if v is not None else None
    return None


def _norm_addr(node: Optional[ast.AST]) -> Tuple[Optional[str], int]:
    """Normalize an SPM address expression into (base, const_offset):
    ``slot + 8`` -> ("slot", 8), ``64`` -> (None, 64), anything else ->
    (dump-of-base, folded offset). Two addresses are only comparable when
    their bases are equal."""
    if node is None:
        return ("<none>", 0)
    c = _const_int(node)
    if c is not None:
        return (None, c)
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.Add,
                                                            ast.Sub)):
        rc = _const_int(node.right)
        if rc is not None:
            base, off = _norm_addr(node.left)
            return (base if base is not None else "<const>",
                    off + (rc if isinstance(node.op, ast.Add) else -rc))
        lc = _const_int(node.left)
        if lc is not None and isinstance(node.op, ast.Add):
            base, off = _norm_addr(node.right)
            return (base if base is not None else "<const>", off + lc)
    return (ast.dump(node), 0)


def _names_in(node: Optional[ast.AST]) -> Set[str]:
    if node is None:
        return set()
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _wait_of(call: ast.Call, method: Optional[str],
             cls: Optional[str]) -> bool:
    """Does this issue command suspend until completion (wait=True)?"""
    if cls in ("AloadNoWait", "AstoreNoWait"):
        return False
    if cls in ("AloadVec", "AstoreVec"):
        w = _arg(call, 3, "wait")
        if w is None:
            return False                  # dataclass default: wait=False
        return not (isinstance(w, ast.Constant) and w.value is False)
    # facade: aload/astore/aload_vec/astore_vec default wait=True
    for kw in call.keywords:
        if kw.arg == "wait":
            return not (isinstance(kw.value, ast.Constant)
                        and kw.value.value is False)
    return True


@dataclass
class _Window:
    """An in-flight wait=False load destination [base+off, base+off+size)."""
    base: Optional[str]
    off: int
    size: Optional[int]            # None = unknown (treated as 1 byte)
    toks: frozenset                # names the wait token may flow into
    line: int

    def overlaps(self, base, off, size) -> bool:
        if self.base != base:
            return False
        a0, a1 = self.off, self.off + (self.size or 1)
        b0, b1 = off, off + (size or 1)
        return a0 < b1 and b0 < a1


# ========================================================================
# Per-function analysis
# ========================================================================

class _FuncLinter:
    def __init__(self, fn: ast.FunctionDef, filename: str,
                 findings: List[Finding]):
        self.fn = fn
        self.filename = filename
        self.findings = findings
        self.flow = self._flow_edges()

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule, message, self.filename, getattr(node, "lineno", 0),
            getattr(node, "col_offset", 0), self.fn.name))

    # ------------------------------------------------------ name flow
    def _flow_edges(self) -> Dict[str, Set[str]]:
        """name -> names it flows into, via assignment / append / extend /
        subscript store (one hop; closures take the transitive closure)."""
        edges: Dict[str, Set[str]] = {}
        for node in _walk_own(self.fn):
            if isinstance(node, ast.Assign):
                srcs = _names_in(node.value)
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        for s in srcs:
                            edges.setdefault(s, set()).add(tgt.id)
                    elif isinstance(tgt, ast.Subscript) and \
                            isinstance(tgt.value, ast.Name):
                        for s in srcs:
                            edges.setdefault(s, set()).add(tgt.value.id)
            elif isinstance(node, ast.AugAssign) and \
                    isinstance(node.target, ast.Name):
                for s in _names_in(node.value):
                    edges.setdefault(s, set()).add(node.target.id)
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in ("append", "extend", "add")
                  and isinstance(node.func.value, ast.Name)):
                for a in node.args:
                    for s in _names_in(a):
                        edges.setdefault(s, set()).add(node.func.value.id)
        return edges

    def closure(self, name: str) -> frozenset:
        seen = {name}
        queue = [name]
        while queue:
            for nxt in self.flow.get(queue.pop(), ()):
                if nxt not in seen:
                    seen.add(nxt)
                    queue.append(nxt)
        return frozenset(seen)

    # -------------------------------------------------------- structure
    def _parents(self) -> Dict[ast.AST, ast.AST]:
        par: Dict[ast.AST, ast.AST] = {}
        stack = [self.fn]
        while stack:
            node = stack.pop()
            for child in ast.iter_child_nodes(node):
                par[child] = node
                if not isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef, ast.Lambda)):
                    stack.append(child)
        return par

    def _if_chain(self, node: ast.AST,
                  parents: Dict[ast.AST, ast.AST]) -> Set[int]:
        """ids of the If nodes (branch bodies) strictly enclosing `node`."""
        chain: Set[int] = set()
        cur = node
        while cur in parents:
            nxt = parents[cur]
            if isinstance(nxt, ast.If):
                chain.add(id(nxt))
            cur = nxt
        return chain

    # ------------------------------------------------------------- run
    def run(self) -> None:
        self._lint_yields_and_bypass()
        self._lint_leaks()
        self._lint_spm_races()
        self._lint_locks()

    # ------------------------------------------- AMI005 / AMI006
    def _lint_yields_and_bypass(self) -> None:
        for node in _walk_own(self.fn):
            if isinstance(node, ast.Yield):
                v = node.value
                if v is None:
                    self.emit("AMI005", node,
                              "bare yield — every yield must produce an "
                              "AMI command (ctx.<op>(...))")
                    continue
                m = _ctx_method(v)
                if m is not None:
                    if m not in FACADE_METHODS:
                        self.emit("AMI005", v,
                                  f"unknown ctx method ctx.{m}(...) — not "
                                  f"part of the AMI command facade")
                    continue
                if _command_class(v) is not None:
                    continue
                self.emit("AMI005", v,
                          "yield of a non-command expression — ports must "
                          "yield ctx.<op>(...) (or a command dataclass)")
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in ENGINE_SURFACE
                  and not (isinstance(node.func.value, ast.Name)
                           and node.func.value.id == "ctx")):
                recv = (node.func.value.id
                        if isinstance(node.func.value, ast.Name)
                        else ast.unparse(node.func.value)
                        if hasattr(ast, "unparse") else "<expr>")
                self.emit("AMI006", node,
                          f"direct engine call {recv}.{node.func.attr}(...) "
                          f"bypasses the ctx command facade — the scheduler "
                          f"owns the engine")

    # --------------------------------------------------------- AMI001
    def _issues(self) -> List[dict]:
        """Every wait=False issue yield, with its token binding."""
        parents = self._parents()
        out = []
        for node in _walk_own(self.fn):
            if not isinstance(node, ast.Yield) or node.value is None:
                continue
            call = node.value
            m = _ctx_method(call)
            cls = _command_class(call)
            if m in ("aload", "astore", "aload_vec", "astore_vec"):
                kind = "load" if m.startswith("aload") else "store"
            elif cls in ("Aload", "Astore", "AloadNoWait", "AstoreNoWait",
                         "AloadVec", "AstoreVec"):
                kind = "load" if "load" in cls.lower() else "store"
            else:
                continue
            if not isinstance(call, ast.Call) or _wait_of(call, m, cls):
                continue
            parent = parents.get(node)
            tok: Optional[str] = None
            discarded = False
            if isinstance(parent, ast.Expr):
                discarded = True
            elif isinstance(parent, ast.Assign) and len(parent.targets) == 1 \
                    and isinstance(parent.targets[0], ast.Name):
                tok = parent.targets[0].id
            out.append({"node": node, "call": call, "kind": kind,
                        "tok": tok, "discarded": discarded,
                        "parents": parents})
        return out

    def _await_nodes(self) -> List[Tuple[ast.AST, Set[str]]]:
        out = []
        for node in _walk_own(self.fn):
            m = _ctx_method(node)
            cls = _command_class(node)
            if m in ("await_rid", "await_rids") or cls in ("AwaitRid",
                                                           "AwaitRids"):
                names: Set[str] = set()
                for a in node.args:
                    names |= _names_in(a)
                for kw in node.keywords:
                    names |= _names_in(kw.value)
                out.append((node, names))
        return out

    def _lint_leaks(self) -> None:
        issues = self._issues()
        if not issues:
            return
        awaits = self._await_nodes()
        for iss in issues:
            node = iss["node"]
            if iss["discarded"]:
                self.emit("AMI001", node,
                          f"wait=False {iss['kind']} issue discards its "
                          f"wait token — the request ID leaks (no await "
                          f"ever retires it)")
                continue
            if iss["tok"] is None:
                continue                 # bound into a structure we can't
            clo = self.closure(iss["tok"])      # follow: stay quiet
            hits = [(n, names) for n, names in awaits if clo & names]
            if not hits:
                self.emit("AMI001", node,
                          f"wait token {iss['tok']!r} from this "
                          f"wait=False {iss['kind']} never reaches an "
                          f"await_rid/await_rids — leaked request ID")
                continue
            parents = iss["parents"]
            issue_ifs = self._if_chain(node, parents)
            if all(self._if_chain(n, parents) - issue_ifs for n, _ in hits):
                self.emit("AMI001", node,
                          f"wait token {iss['tok']!r} is only awaited "
                          f"inside a conditional branch — the request ID "
                          f"may leak on some path")

    # --------------------------------------------------------- AMI002
    def _lint_spm_races(self) -> None:
        self._scan_block(self.fn.body, [])

    def _scan_block(self, stmts: Sequence[ast.stmt],
                    state: List[_Window]) -> List[_Window]:
        for stmt in stmts:
            if isinstance(stmt, (ast.For, ast.While)):
                # one abstract iteration; windows awaited inside the body
                # stay cleared (out = body_out, which is (in - awaited) +
                # surviving additions). Back-edge races are not modeled.
                state = self._scan_block(stmt.body, state)
                state = self._scan_block(stmt.orelse, state)
            elif isinstance(stmt, ast.If):
                a = self._scan_block(stmt.body, list(state))
                b = self._scan_block(stmt.orelse, list(state))
                merged: List[_Window] = []
                seen: Set[int] = set()
                for w in a + b:
                    if id(w) not in seen:
                        seen.add(id(w))
                        merged.append(w)
                state = merged
            elif isinstance(stmt, ast.With):
                state = self._scan_block(stmt.body, state)
            elif isinstance(stmt, ast.Try):
                state = self._scan_block(stmt.body, state)
                for h in stmt.handlers:
                    state = self._scan_block(h.body, state)
                state = self._scan_block(stmt.orelse, state)
                state = self._scan_block(stmt.finalbody, state)
            else:
                state = self._scan_simple(stmt, state)
        return state

    def _scan_simple(self, stmt: ast.stmt,
                     state: List[_Window]) -> List[_Window]:
        events = []
        for node in _walk_own(stmt):
            m = _ctx_method(node)
            cls = _command_class(node)
            if m is not None or cls is not None:
                events.append((getattr(node, "lineno", 0),
                               getattr(node, "col_offset", 0), node, m, cls))
        events.sort(key=lambda e: (e[0], e[1]))
        tok_name = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name):
            tok_name = stmt.targets[0].id
        for _, _, call, m, cls in events:
            state = self._apply_event(stmt, call, m, cls, tok_name, state)
        return state

    def _apply_event(self, stmt, call, m, cls, tok_name,
                     state: List[_Window]) -> List[_Window]:
        # awaits clear the windows their tokens flow into
        if m in ("await_rid", "await_rids") or cls in ("AwaitRid",
                                                       "AwaitRids"):
            names: Set[str] = set()
            for a in call.args:
                names |= _names_in(a)
            for kw in call.keywords:
                names |= _names_in(kw.value)
            return [w for w in state if not (w.toks & names)]
        # synchronous SPM accesses race against live windows
        if m == "spm_read" or cls == "SpmRead":
            base, off = _norm_addr(_arg(call, 0, "spm"))
            size = _const_int(_arg(call, 1, "size"))
            self._check_race(call, "spm_read", base, off, size, state)
            return state
        if m == "spm_write" or cls == "SpmWrite":
            base, off = _norm_addr(_arg(call, 0, "spm"))
            self._check_race(call, "spm_write", base, off, None, state)
            return state
        # issues: wait=False loads open windows
        is_load = (m in ("aload", "aload_vec")
                   or cls in ("Aload", "AloadNoWait", "AloadVec"))
        is_store = (m in ("astore", "astore_vec")
                    or cls in ("Astore", "AstoreNoWait", "AstoreVec"))
        if not (is_load or is_store):
            return state
        if _wait_of(call, m, cls):
            return state                     # wait=True: retired on resume
        if not is_load:
            return state                     # store payload captured at issue
        toks = self.closure(tok_name) if tok_name else frozenset()
        vec = m in ("aload_vec",) or cls == "AloadVec"
        spm = _arg(call, 0, "spm")
        size = _const_int(_arg(call, 2, "size"))
        if vec:
            base = ast.dump(spm) if spm is not None else "<none>"
            win = _Window(base, 0, None, toks, getattr(call, "lineno", 0))
        else:
            base, off = _norm_addr(spm)
            win = _Window(base, off, size, toks, getattr(call, "lineno", 0))
        state = list(state)
        state.append(win)
        return state

    def _check_race(self, node, what, base, off, size,
                    state: List[_Window]) -> None:
        for w in state:
            if w.overlaps(base, off, size):
                self.emit("AMI002", node,
                          f"{what} may overlap the destination of the "
                          f"in-flight wait=False aload issued at line "
                          f"{w.line} — await its token first")
                return

    # --------------------------------------------------- AMI003 / AMI004
    def _lint_locks(self) -> None:
        acquires: List[Tuple[str, ast.AST, Optional[int]]] = []
        releases: List[Tuple[str, ast.AST]] = []
        vec_acq: List[Tuple[str, ast.AST]] = []
        vec_rel: List[Tuple[str, ast.AST]] = []
        ordered = []
        for node in _walk_own(self.fn):
            m = _ctx_method(node)
            cls = _command_class(node)
            if m is None and cls is None:
                continue
            key = m or {"Acquire": "acquire", "Release": "release",
                        "AcquireVec": "acquire_vec",
                        "ReleaseVec": "release_vec"}.get(cls)
            if key not in ("acquire", "release", "acquire_vec",
                           "release_vec"):
                continue
            arg = _arg(node, 0, "addr" if key in ("acquire", "release")
                       else "addrs")
            dump = ast.dump(arg) if arg is not None else "<none>"
            ordered.append((getattr(node, "lineno", 0),
                            getattr(node, "col_offset", 0), key, node, arg,
                            dump))
        ordered.sort(key=lambda e: (e[0], e[1]))
        held_consts: List[Tuple[int, ast.AST]] = []
        for _, _, key, node, arg, dump in ordered:
            if key == "acquire":
                acquires.append((dump, node, _const_int(arg)))
                c = _const_int(arg)
                if c is not None:
                    for h, _ in held_consts:
                        if c <= h:
                            self.emit(
                                "AMI004", node,
                                f"acquire({c}) while holding lock {h} "
                                f"breaks the ascending lock order — "
                                f"deadlock risk across tasks")
                            break
                    held_consts.append((c, node))
            elif key == "release":
                releases.append((dump, node))
                c = _const_int(arg)
                if c is not None:
                    held_consts = [(h, n) for h, n in held_consts if h != c]
            elif key == "acquire_vec":
                vec_acq.append((dump, node))
                if isinstance(arg, (ast.List, ast.Tuple)):
                    consts = [_const_int(e) for e in arg.elts]
                    if all(c is not None for c in consts) and \
                            consts != sorted(set(consts)):
                        self.emit(
                            "AMI004", node,
                            f"acquire_vec addrs {consts} are not strictly "
                            f"ascending and distinct — the AcquireVec "
                            f"contract (see workloads._lock_set)")
            else:
                vec_rel.append((dump, node))
        rel_dumps = [d for d, _ in releases]
        for dump, node, _ in acquires:
            if dump in rel_dumps:
                rel_dumps.remove(dump)
            else:
                self.emit("AMI003", node,
                          "Acquire without a matching Release of the same "
                          "address — the lock block is held forever")
        for dump in set(rel_dumps):
            node = next(n for d, n in releases if d == dump)
            self.emit("AMI003", node,
                      "Release without a matching Acquire of the same "
                      "address")
        va = [d for d, _ in vec_acq]
        for dump, node in vec_acq:
            if dump not in (d for d, _ in vec_rel):
                self.emit("AMI003", node,
                          "acquire_vec without a matching release_vec of "
                          "the same lock set")
        for dump, node in vec_rel:
            if dump not in va:
                self.emit("AMI003", node,
                          "release_vec without a matching acquire_vec of "
                          "the same lock set")


# ========================================================================
# Module / file / registry entry points
# ========================================================================

def _is_port_generator(fn: ast.FunctionDef) -> bool:
    for node in _walk_own(fn):
        if isinstance(node, ast.Yield) and node.value is not None:
            if _ctx_method(node.value) is not None or \
                    _command_class(node.value) is not None:
                return True
    return False


def _suppressions(src: str) -> Dict[int, Optional[Set[str]]]:
    """line -> None (suppress all) or set of rules to suppress."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            rules = m.group(1)
            out[i] = ({r.strip() for r in rules.split(",")} if rules
                      else None)
    return out


def lint_source(src: str, filename: str = "<string>") -> List[Finding]:
    """Lint every port generator in `src`; returns surviving findings."""
    tree = ast.parse(src, filename=filename)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and _is_port_generator(node):
            _FuncLinter(node, filename, findings).run()
    sup = _suppressions(src)
    kept = []
    for f in findings:
        rules = sup.get(f.line, False)
        if rules is False:
            kept.append(f)
        elif rules is not None and f.rule not in rules:
            kept.append(f)
    kept.sort(key=lambda f: (f.file, f.line, f.col, f.rule))
    return kept


def lint_file(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        return lint_source(fh.read(), filename=path)


def lint_registry(registry=None) -> List[Finding]:
    """Lint the source module of every registered ``@workload`` builder
    (deduplicated): the in-repo ports plus anything the caller imported."""
    if registry is None:
        from repro.amu import REGISTRY as registry
    findings: List[Finding] = []
    for path in registry.source_files():
        findings.extend(lint_file(path))
    return findings


def render(findings: List[Finding], as_json: bool = False) -> str:
    if as_json:
        return json.dumps({"findings": [f.to_dict() for f in findings],
                           "count": len(findings)}, indent=2)
    if not findings:
        return "amilint: 0 findings"
    lines = [str(f) for f in findings]
    lines.append(f"amilint: {len(findings)} finding(s)")
    return "\n".join(lines)
