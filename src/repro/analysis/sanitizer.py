"""Runtime AMI protocol sanitizer — a TSan-style shadow-state checker.

``AmuConfig(sanitize=True)`` attaches one :class:`AmiSanitizer` per
engine+scheduler stack (every rack core gets its own). The sanitizer
observes the duck-typed hooks the engine and scheduler expose and keeps
shadow state only:

* an **SPM shadow allocation map** — one ``int64`` per SPM data byte,
  holding the rid of the in-flight LOAD targeting that byte (0 = free).
  Synchronous ``spm_read``/``spm_write`` and astore payload captures that
  touch a nonzero byte are data races; a new load landing on a nonzero
  byte is an overlapping in-flight DMA destination. This is the scalar
  oracle's ``_assert_no_inflight_load_overlap`` promoted to a uniform
  contract across the batched and epoch-fused engines (which otherwise
  check nothing) — same message format, plus rid/port attribution.
* a **rid/token lifecycle tracker** — every wait token the scheduler
  mints must be awaited before the port exits; :meth:`finish` raises a
  leak report for issued-never-awaited tokens (a leaked AMART entry in
  hardware).
* a **lock-order graph** — ``Acquire``/``AcquireVec`` edges (held -> new)
  with incremental cycle detection (a cycle is a potential disambiguator
  deadlock, reported *before* the simulated deadlock fires), duplicate
  same-task acquires (self-deadlock), releases of un-held blocks, and
  the AcquireVec ascending/distinct contract.

Neutrality is the design invariant: hooks never touch the clock, the
far-model RNG, stats, traces, or any engine/scheduler state — with
``sanitize=True`` every run is bit-identical to ``sanitize=False``
(tests/test_sanitizer.py pins traces, stats and RNG bitstreams).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.engine import LOAD, format_race


class AmiProtocolError(AssertionError):
    """An AMI protocol violation caught by the runtime sanitizer."""


class AmiSanitizer:
    """Shadow-state checker for one engine + scheduler stack.

    Wire-up (done by :class:`repro.amu.session.AmuSession` /
    ``RackSession`` when ``AmuConfig(sanitize=True)``)::

        san = AmiSanitizer(port=inst.name, label="core3")
        san.attach(engine, scheduler)
        ... run ...
        san.finish()      # leak report (raises AmiProtocolError)
    """

    def __init__(self, port: str = "", label: str = ""):
        self.port = port
        self.label = label
        self.engine = None
        self.sched = None
        # SPM shadow map + rid-indexed in-flight load windows (SoA mirror)
        self._shadow = np.empty(0, np.int64)
        self._w_lo = np.empty(0, np.int64)
        self._w_sz = np.empty(0, np.int64)
        # token lifecycle: tokens are minted sequentially (1.._tok); the
        # awaited set is cleared when the scheduler recycles its maps (a
        # quiesce point — leaked tokens block recycling via the unclaimed
        # count, so nothing under suspicion is ever dropped)
        self._awaited: Set[int] = set()
        # lock plane: per-task held block lists + global order graph
        self._held: Dict[int, List[int]] = {}
        self._edges: Dict[int, Set[int]] = {}
        self._block_shift = 6

    # ------------------------------------------------------------- wiring
    def attach(self, engine, sched) -> None:
        self.engine = engine
        self.sched = sched
        engine.sanitizer = self
        sched._san = self
        self._shadow = np.zeros(engine.spm_data_bytes, np.int64)
        cap = engine.config.queue_length
        self._w_lo = np.zeros(cap + 1, np.int64)
        self._w_sz = np.zeros(cap + 1, np.int64)
        if sched.disamb is not None:
            self._block_shift = sched.disamb.block_shift

    def _where(self) -> str:
        return f"{self.label}: " if self.label else ""

    def _grow_windows(self, rid: int) -> None:
        extra = rid + 1 - self._w_lo.size
        self._w_lo = np.concatenate([self._w_lo, np.zeros(extra, np.int64)])
        self._w_sz = np.concatenate([self._w_sz, np.zeros(extra, np.int64)])

    # ------------------------------------------------------- engine hooks
    def on_issue(self, kind: int, rid: int, spm_addr: int, size: int) -> None:
        """A scalar aload/astore was issued (request now in flight)."""
        win = self._shadow[spm_addr:spm_addr + size]
        nz = win.nonzero()[0]
        if nz.size:
            other = int(win[nz[0]])
            what = ("aload destination" if kind == LOAD
                    else "astore payload capture")
            raise AmiProtocolError(format_race(
                self._where(), what, spm_addr, spm_addr + size, other,
                int(self._w_lo[other]),
                int(self._w_lo[other] + self._w_sz[other]), self.port))
        if kind == LOAD:
            if rid >= self._w_lo.size:
                self._grow_windows(rid)
            win[:] = rid
            self._w_lo[rid] = spm_addr
            self._w_sz[rid] = size

    def on_issue_batch(self, kind: int, rids, spm_addrs, sizes) -> None:
        """A whole issue batch (aload_batch/astore_batch/stage_epoch)."""
        k = len(rids)
        if k == 0:
            return
        if k == 1:
            self.on_issue(kind, int(rids[0]), int(spm_addrs[0]),
                          int(sizes[0]))
            return
        spm_addrs = np.asarray(spm_addrs, np.int64)
        sizes = np.asarray(sizes, np.int64)
        if (sizes == sizes[0]).all():
            g = int(sizes[0])
            flat = (spm_addrs[:, None] + np.arange(g)).ravel()
        else:
            flat = np.concatenate(
                [np.arange(a, a + s) for a, s in
                 zip(spm_addrs.tolist(), sizes.tolist())])
        vals = self._shadow[flat]
        nz = vals.nonzero()[0]
        if nz.size:
            i = int(nz[0])
            other = int(vals[i])
            what = ("aload destination" if kind == LOAD
                    else "astore payload capture")
            raise AmiProtocolError(format_race(
                self._where(), what, int(flat[i]), int(flat[i]) + 1, other,
                int(self._w_lo[other]),
                int(self._w_lo[other] + self._w_sz[other]), self.port))
        if kind != LOAD:
            return
        if np.unique(flat).size != flat.size:
            raise AmiProtocolError(
                f"{self._where()}aload batch has overlapping destination "
                f"windows within one issue (port {self.port!r})")
        rids = np.asarray(rids, np.int64)
        if int(rids.max()) >= self._w_lo.size:
            self._grow_windows(int(rids.max()))
        self._shadow[flat] = np.repeat(rids, sizes)
        self._w_lo[rids] = spm_addrs
        self._w_sz[rids] = sizes

    def on_retire(self, rids) -> None:
        """Requests retired by ``advance`` — their DMA is no longer in
        flight (failed requests included: the window is released even
        though no data moved)."""
        rids = np.asarray(rids, np.int64)
        if rids.size == 0:
            return
        rids = rids[rids < self._w_lo.size]
        sz = self._w_sz[rids]
        loads = rids[sz > 0]
        if loads.size == 0:
            return
        lo = self._w_lo[loads]
        g = self._w_sz[loads]
        if (g == g[0]).all():
            self._shadow[(lo[:, None] + np.arange(int(g[0]))).ravel()] = 0
        else:
            for a, s in zip(lo.tolist(), g.tolist()):
                self._shadow[a:a + s] = 0
        self._w_sz[loads] = 0

    def on_spm_access(self, spm_addr: int, size: int, what: str) -> None:
        """Synchronous spm_read/spm_write about to touch [addr, addr+size)."""
        win = self._shadow[spm_addr:spm_addr + size]
        nz = win.nonzero()[0]
        if nz.size:
            rid = int(win[nz[0]])
            raise AmiProtocolError(format_race(
                self._where(), what, spm_addr, spm_addr + size, rid,
                int(self._w_lo[rid]), int(self._w_lo[rid] + self._w_sz[rid]),
                self.port))

    # ---------------------------------------------------- scheduler hooks
    def on_await(self, toks) -> None:
        """Tokens passed to ``_await_tokens`` (issued -> awaited)."""
        self._awaited.update(int(t) for t in toks)

    def on_token_recycle(self) -> None:
        """The scheduler recycled its token maps at a quiesce point; token
        numbering restarts, and every outstanding token was awaited (leaked
        tokens hold the unclaimed count nonzero, which blocks recycling)."""
        self._awaited.clear()

    def on_acquire(self, tid: int, addrs, vec: bool = False) -> None:
        """Task `tid` acquires lock blocks for `addrs` (in order)."""
        if vec:
            seq = [int(a) for a in addrs]
            if seq != sorted(set(seq)):
                raise AmiProtocolError(
                    f"{self._where()}AcquireVec addrs must be strictly "
                    f"ascending and distinct (port {self.port!r}): {seq[:8]}")
        held = self._held.setdefault(tid, [])
        for a in addrs:
            b = int(a) >> self._block_shift
            if b in held:
                raise AmiProtocolError(
                    f"{self._where()}task re-acquires lock block {b} it "
                    f"already holds — self-deadlock (port {self.port!r})")
            for h in held:
                self._order_edge(h, b)
            held.append(b)

    def on_release(self, tid: int, addrs) -> None:
        held = self._held.get(tid)
        for a in addrs:
            b = int(a) >> self._block_shift
            if held is None or b not in held:
                raise AmiProtocolError(
                    f"{self._where()}Release of lock block {b} that the "
                    f"task does not hold (port {self.port!r})")
            held.remove(b)

    def _order_edge(self, u: int, v: int) -> None:
        """Record lock-order edge u -> v; a path v ~> u means adding it
        closes a cycle — two tasks can interleave into a deadlock."""
        succ = self._edges.setdefault(u, set())
        if v in succ:
            return
        path = self._find_path(v, u)
        if path is not None:
            cyc = " -> ".join(str(b) for b in [u, v, *path[1:]])
            raise AmiProtocolError(
                f"{self._where()}lock-order cycle {cyc} (port "
                f"{self.port!r}); acquire blocks in one global ascending "
                f"order (see workloads._lock_set)")
        succ.add(v)

    def _find_path(self, src: int, dst: int) -> Optional[List[int]]:
        stack = [(src, [src])]
        seen = {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self._edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    # ------------------------------------------------------- exit report
    def finish(self) -> None:
        """Port-exit leak report: raises on issued-never-awaited tokens and
        on locks still held after every task finished."""
        sched = self.sched
        if sched is not None:
            hi = int(sched._tok)
            leaked = [t for t in range(1, hi + 1) if t not in self._awaited]
            if leaked:
                raise AmiProtocolError(
                    f"{self._where()}port {self.port!r} leaked "
                    f"{len(leaked)} request token(s) — issued but never "
                    f"awaited (leaked AMART entries): {leaked[:8]}"
                    f"{'...' if len(leaked) > 8 else ''}")
        still = sorted(b for blocks in self._held.values() for b in blocks)
        if still:
            raise AmiProtocolError(
                f"{self._where()}port {self.port!r} exited holding "
                f"{len(still)} lock block(s) (Acquire without Release): "
                f"{still[:8]}{'...' if len(still) > 8 else ''}")
