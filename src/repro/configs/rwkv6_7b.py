"""rwkv6-7b "Finch" — attention-free, data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536. Head size 64 -> 64 mixing heads.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import BLOCK_RWKV6, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,       # rwkv6 head_size=64 -> 4096/64 heads
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=(BLOCK_RWKV6,),
    rnn_width=4096,
    activation="swiglu",
    norm="layernorm",
    source="[arXiv:2404.05892; hf]",
    notes="attention-free; sub-quadratic -> runs long_500k",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        block_pattern=(BLOCK_RWKV6,),
        rnn_width=64,
        norm="layernorm",
    )
