"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1:2 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, head_dim=256,
sliding window 2048 on the attention layers. [arXiv:2402.19427; unverified]
"""
from repro.configs.base import (BLOCK_LOCAL, BLOCK_RGLRU, ModelConfig)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=(BLOCK_RGLRU, BLOCK_RGLRU, BLOCK_LOCAL),
    window_size=2048,
    rnn_width=4096,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    source="[arXiv:2402.19427; unverified]",
    notes="RG-LRU + local attn 1:2; sub-quadratic -> runs long_500k",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        block_pattern=(BLOCK_RGLRU, BLOCK_RGLRU, BLOCK_LOCAL),
        window_size=16,
        rnn_width=64,
        activation="geglu",
        tie_embeddings=True,
    )
