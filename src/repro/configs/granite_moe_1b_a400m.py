"""granite-moe-1b-a400m — small MoE, 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff_expert=512 vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import BLOCK_FULL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    block_pattern=(BLOCK_FULL,),
    tie_embeddings=True,
    activation="swiglu",
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512),
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
    notes="32 experts top-8; long_500k skipped (pure full attention)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=512,
        tie_embeddings=True,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32),
    )
