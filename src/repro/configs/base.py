"""Config dataclasses for models, shapes, parallelism, and the AMU engine.

Every assigned architecture is expressed as a :class:`ModelConfig`. Configs are
frozen dataclasses so they can be hashed into jit static arguments and compared
structurally in tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds: per-layer token-mixing modules. `block_pattern` is cycled over
# the layer stack (e.g. RecurrentGemma's ("rglru", "rglru", "local") 1:2 mix).
# ---------------------------------------------------------------------------
BLOCK_FULL = "full"      # full causal (or bidirectional for encoders) attention
BLOCK_LOCAL = "local"    # sliding-window attention
BLOCK_RGLRU = "rglru"    # RG-LRU linear recurrence (RecurrentGemma / Griffin)
BLOCK_RWKV6 = "rwkv6"    # RWKV-6 "Finch" data-dependent decay mixer

SUBQUADRATIC_BLOCKS = frozenset({BLOCK_RGLRU, BLOCK_RWKV6, BLOCK_LOCAL})


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    router_jitter: float = 0.0
    load_balance_loss_weight: float = 0.01
    # capacity factor for dropless-vs-capacity dispatch; the dense-routing path
    # used for dry-runs ignores it, the dispatch kernel honours it.
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: the dry-run/input pipeline provides precomputed
    patch/frame embeddings; only the projection into d_model is modeled."""
    kind: str                 # "vision" | "audio"
    feature_dim: int          # dim of the precomputed embeddings
    prefix_len: int = 0       # vision: number of patch positions at seq start


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = (BLOCK_FULL,)
    window_size: int = 0      # for BLOCK_LOCAL
    qkv_bias: bool = False
    tie_embeddings: bool = False
    activation: str = "swiglu"   # swiglu | geglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # Qwen2-VL M-RoPE (t, h, w) splits
    rnn_width: int = 0        # rglru/rwkv6 recurrence width (0 -> d_model)
    causal: bool = True       # False for encoder-only (hubert)
    is_decoder: bool = True   # False -> no decode/serve step (encoder-only)
    moe: Optional[MoEConfig] = None
    frontend: Optional[FrontendConfig] = None
    source: str = ""          # provenance note "[arXiv:...; tier]"
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def is_subquadratic(self) -> bool:
        """True if *every* layer avoids full quadratic attention (long_500k ok)."""
        return all(k in SUBQUADRATIC_BLOCKS for k in self.layer_kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        total = emb + head + d  # final norm
        for kind in self.layer_kinds:
            total += 2 * d  # pre norms
            if kind in (BLOCK_FULL, BLOCK_LOCAL):
                qkv = d * (n_q * hd) + 2 * d * (n_kv * hd)
                if self.qkv_bias:
                    qkv += (n_q + 2 * n_kv) * hd
                total += qkv + (n_q * hd) * d
            elif kind == BLOCK_RGLRU:
                w = self.rnn_width or d
                # input/gate projections + recurrence params + out proj
                total += 2 * d * w + 3 * w + w * d + w * w // max(self.num_heads, 1)
            elif kind == BLOCK_RWKV6:
                w = self.rnn_width or d
                # r,k,v,g,decay projections + out proj + mix/decay/bonus vecs
                total += 5 * d * w + w * d + 7 * d
            if self.moe is not None:
                m = self.moe
                total += d * m.num_experts  # router
                total += m.num_experts * 3 * d * m.d_ff_expert
                total += m.num_shared_experts * 3 * d * m.d_ff_expert
            else:
                n_mat = 3 if self.activation in ("swiglu", "geglu") else 2
                total += n_mat * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_like = dataclasses.replace(self, moe=None, d_ff=0)
        base = dense_like.param_count() - 2 * self.num_layers * 0
        active_ffn = self.num_layers * (
            self.d_model * m.num_experts  # router always runs
            + (m.top_k + m.num_shared_experts) * 3 * self.d_model * m.d_ff_expert
        )
        return base + active_ffn


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------
KIND_TRAIN = "train"
KIND_PREFILL = "prefill"
KIND_DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == KIND_DECODE:
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, KIND_TRAIN)
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, KIND_PREFILL)
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, KIND_DECODE)
LONG_500K = ShapeConfig("long_500k", 524288, 1, KIND_DECODE)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable, reason). Encoder-only archs skip decode; pure full-attention
    archs skip long_500k (needs sub-quadratic mixing) per the assignment."""
    if shape.kind == KIND_DECODE and not model.is_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not model.is_subquadratic:
        return False, "pure full-attention arch: 512k decode needs sub-quadratic mixing"
    if shape.kind == KIND_PREFILL and not model.is_decoder:
        # encoder forward over 32k frames is well-defined; keep it.
        return True, "encoder forward (no KV cache)"
    return True, ""


# ---------------------------------------------------------------------------
# Parallelism / runtime
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParallelConfig:
    fsdp: bool = False           # shard params over the data axis (ZeRO-3 style)
    zero1: bool = True           # shard optimizer state over the data axis
    seq_shard: bool = False      # sequence parallelism over the data axis
    remat: str = "selective"     # none | selective | full
    scan_layers: bool = True
    expert_parallel: bool = True # shard MoE experts over the model axis
    donate_state: bool = True
    grad_compression: str = "none"  # none | int8 (error-feedback)
    overlap_collectives: bool = True  # latency-hiding pass in sharding rules
    microbatches: int = 1        # gradient-accumulation steps per train step


@dataclass(frozen=True)
class EngineConfig:
    """AsyncMemoryEngine (the paper's AMU) configuration.

    Mirrors Table 1's configuration registers: `queue_length` == number of
    outstanding request slots (paper: SPM metadata area length), `granularity`
    == bytes moved per aload/astore, `spm_bytes` == SPM capacity (paper: 64 KB
    of L2; here: the VMEM slot-ring budget).
    """
    queue_length: int = 256
    granularity: int = 64
    spm_bytes: int = 64 * 1024
    batch_ids: int = 31          # list-vector register capacity (paper: 31 IDs)
    disambiguation: str = "software"  # software | none


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = ParallelConfig()
    engine: EngineConfig = EngineConfig()
    seed: int = 0
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    steps: int = 200
    checkpoint_every: int = 50
    microbatch: int = 0          # 0 -> no gradient accumulation
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
