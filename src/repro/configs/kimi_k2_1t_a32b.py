"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) d_ff_expert=2048 vocab=163840,
MoE 384 experts top-8 + 1 shared expert. head_dim pinned to 128 for MXU
alignment (7168/64=112 is not 128-aligned; the o-proj absorbs the difference).
[arXiv:2501.kimi2; unverified]
"""
from repro.configs.base import BLOCK_FULL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,  # == expert d_ff; dense path unused (all layers MoE)
    vocab_size=163840,
    block_pattern=(BLOCK_FULL,),
    activation="swiglu",
    rope_theta=50000.0,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1),
    source="[arXiv:2501.kimi2; unverified]",
    notes=("~1.03T total / ~32B active params; expert-parallel over the model "
           "axis (384/16 = 24 experts per group); long_500k skipped "
           "(pure full attention)"),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared_experts=1),
    )
