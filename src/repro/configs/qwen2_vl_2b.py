"""qwen2-vl-2b — VLM decoder backbone with M-RoPE.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. The vision frontend is
a STUB: input_specs() provides precomputed patch embeddings for a 256-position
image prefix. [arXiv:2409.12191; hf]
"""
from repro.configs.base import BLOCK_FULL, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    block_pattern=(BLOCK_FULL,),
    qkv_bias=True,
    tie_embeddings=True,
    activation="swiglu",
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),  # temporal/height/width splits of head_dim/2
    frontend=FrontendConfig(kind="vision", feature_dim=1280, prefix_len=256),
    source="[arXiv:2409.12191; hf]",
    notes="M-RoPE, dynamic resolution (frontend stubbed as patch embeddings)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        tie_embeddings=True,
        mrope_sections=(2, 3, 3),
        frontend=FrontendConfig(kind="vision", feature_dim=32, prefix_len=8),
    )
