"""qwen2.5-3b — dense GQA decoder with QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
[hf:Qwen/Qwen2.5-0.5B family card; hf]
"""
from repro.configs.base import BLOCK_FULL, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    block_pattern=(BLOCK_FULL,),
    qkv_bias=True,
    tie_embeddings=True,
    activation="swiglu",
    rope_theta=1000000.0,
    source="[hf:Qwen/Qwen2.5-0.5B; hf]",
    notes="GQA + QKV bias; long_500k skipped (pure full attention)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        qkv_bias=True,
        tie_embeddings=True,
    )
