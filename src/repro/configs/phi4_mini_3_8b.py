"""phi4-mini-3.8b — dense GQA decoder, RoPE + SwiGLU, no QKV bias.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064. [arXiv:2412.08905; hf]
"""
from repro.configs.base import BLOCK_FULL, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    block_pattern=(BLOCK_FULL,),
    qkv_bias=False,
    tie_embeddings=True,
    activation="swiglu",
    rope_theta=10000.0,
    source="[arXiv:2412.08905; hf]",
    notes="RoPE SwiGLU GQA; long_500k skipped (pure full attention)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b-smoke",
        family="dense",
        num_layers=2,
        d_model=48,
        num_heads=6,
        num_kv_heads=2,
        head_dim=8,
        d_ff=96,
        vocab_size=512,
        tie_embeddings=True,
    )
