"""qwen2.5-32b — dense GQA decoder with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
[hf:Qwen/Qwen2.5-0.5B family card; hf]
"""
from repro.configs.base import BLOCK_FULL, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    block_pattern=(BLOCK_FULL,),
    qkv_bias=True,
    activation="swiglu",
    rope_theta=1000000.0,
    source="[hf:Qwen/Qwen2.5-0.5B; hf]",
    notes="GQA + QKV bias; long_500k skipped (pure full attention)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=192,
        vocab_size=512,
        qkv_bias=True,
    )
