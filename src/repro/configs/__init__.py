"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (  # noqa: F401  (re-exported public API)
    BLOCK_FULL, BLOCK_LOCAL, BLOCK_RGLRU, BLOCK_RWKV6,
    DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
    EngineConfig, FrontendConfig, ModelConfig, MoEConfig, ParallelConfig,
    RunConfig, ShapeConfig, shape_applicable,
    KIND_TRAIN, KIND_PREFILL, KIND_DECODE,
)

# arch id -> module name under repro.configs
_ARCH_MODULES: Dict[str, str] = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen2-7b": "qwen2_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2.5-3b": "qwen2_5_3b",
    "rwkv6-7b": "rwkv6_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "hubert-xlarge": "hubert_xlarge",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def default_parallel(model: ModelConfig, shape: ShapeConfig) -> ParallelConfig:
    """Per-arch default parallelism on the production meshes.

    FSDP for >= ~7B-param models (params cannot replicate across `data`);
    sequence sharding when the batch can't cover the data axis.
    """
    big = model.param_count() >= 5_000_000_000
    seq_shard = shape.kind != KIND_TRAIN and shape.global_batch < 16
    micro = 1
    if shape.kind == KIND_TRAIN:
        # size the gradient-accumulation factor so the per-microstep saved
        # activation stacks (~3.5 B/token/layer/d_model under full remat)
        # fit alongside params in 16 GB HBM (16-way data sharding assumed)
        tokens_dev = shape.tokens_per_step // 16
        est = tokens_dev * model.d_model * model.num_layers * 3.5
        micro = 1
        while micro < 16 and est / micro > 5e9:
            micro *= 2
    return ParallelConfig(
        fsdp=big,
        zero1=True,
        seq_shard=seq_shard,
        remat="full" if shape.kind == KIND_TRAIN else "none",
        scan_layers=True,
        expert_parallel=model.moe is not None,
        microbatches=micro,
    )


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """Every (arch, shape) pair with its applicability verdict.

    Returns list of (arch_id, shape_name, applicable, reason) — 40 rows.
    """
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            rows.append((arch, shape_name, ok, reason))
    return rows
