"""qwen2-7b — dense GQA decoder with QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. [arXiv:2407.10671; hf]
"""
from repro.configs.base import BLOCK_FULL, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    block_pattern=(BLOCK_FULL,),
    qkv_bias=True,
    activation="swiglu",
    rope_theta=1000000.0,
    source="[arXiv:2407.10671; hf]",
    notes="GQA + QKV bias; long_500k skipped (pure full attention)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
    )
