"""hubert-xlarge — encoder-only audio transformer (w2v2-style backbone).

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (k-means unit targets).
The conv feature extractor is a STUB: input_specs() provides precomputed frame
embeddings. Encoder-only -> no decode shapes. [arXiv:2106.07447; unverified]
"""
from repro.configs.base import BLOCK_FULL, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    block_pattern=(BLOCK_FULL,),
    activation="gelu",
    norm="layernorm",
    causal=False,
    is_decoder=False,
    frontend=FrontendConfig(kind="audio", feature_dim=512),
    source="[arXiv:2106.07447; unverified]",
    notes="encoder-only (bidirectional); decode_32k/long_500k skipped",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        activation="gelu",
        norm="layernorm",
        causal=False,
        is_decoder=False,
        frontend=FrontendConfig(kind="audio", feature_dim=32),
    )
