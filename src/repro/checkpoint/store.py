"""Checkpoint substrate: asynchronous sharded save, manifest-driven restore
with elastic resharding (restore onto a different mesh than the writer's).

Layout:  <dir>/step_<N>/manifest.json + leaf_<i>.npy
Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts the
latest checkpoint; the snapshot is taken synchronously (device -> host) and
the disk write runs on a background thread so the train loop resumes
immediately — the same issue/complete decoupling as everywhere else in this
codebase.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Any


def _flatten_with_paths(tree: Params) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Params, blocking: bool = False,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot now, write asynchronously (unless blocking)."""
        self.wait()                                  # one writer at a time
        flat, _ = _flatten_with_paths(tree)
        host = [(path, np.asarray(jax.device_get(leaf)))
                for path, leaf in flat]
        manifest = {
            "step": step,
            "leaves": [{"path": p, "shape": list(a.shape),
                        "dtype": str(a.dtype), "file": f"leaf_{i}.npy"}
                       for i, (p, a) in enumerate(host)],
            "extra": extra or {},
        }

        def write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, (_, arr) in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        self.wait()
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, step: int, like: Params,
                sharding_fn: Optional[Callable[[str, Any], Any]] = None
                ) -> Tuple[Params, Dict[str, Any]]:
        """Restore into the structure of `like`. `sharding_fn(path, leaf)`
        returns the target Sharding — pass the *new* mesh's shardings to
        reshard elastically (the writer's layout is irrelevant: leaves are
        stored unsharded, placement is decided at restore)."""
        self.wait()
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {leaf["path"]: leaf for leaf in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            rec = by_path[key]
            arr = np.load(os.path.join(d, rec["file"]))
            assert list(arr.shape) == list(leaf.shape), (key, arr.shape,
                                                         leaf.shape)
            if sharding_fn is not None:
                arr = jax.device_put(arr, sharding_fn(key, leaf))
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    def prune(self, keep: int = 3) -> None:
        self.wait()
        all_steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                           if d.startswith("step_")
                           and not d.endswith(".tmp"))
        for s in all_steps[:-keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"))
