from repro.checkpoint.store import CheckpointStore
