"""repro: Asynchronous Memory Access Unit (AMU) as a JAX/TPU framework."""
__version__ = "0.1.0"
