"""Far-memory device model: latency + bandwidth + queueing.

Models the paper's Figure 1/7 memory path: requests leave the core through a
link with finite bandwidth and a base latency that ranges from 0.1 µs (fast
CXL) to 5 µs (cross-switch disaggregated memory). Completion time for a
request issued at `t` is::

    t_done = max(t, link_free) + base_latency + size / bandwidth (+ jitter)

where `link_free` enforces serialization of request injection on the link
(packets inject back-to-back at `size / bandwidth` spacing), giving Little's
law behaviour: sustained MLP on the device cannot exceed
`bandwidth * latency / granularity`.

The same model backs the functional engine (zero-latency mode), the
cycle-approximate simulator, and the runtime's host-offload tier.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

GHZ = 1e9  # cycles are expressed at the simulated core clock (paper: 3 GHz)


@dataclass
class FarMemoryConfig:
    base_latency_cycles: float = 3000.0   # 1 us at 3 GHz
    bandwidth_bytes_per_cycle: float = 21.3  # ~64 GB/s CXL-ish at 3 GHz
    jitter_frac: float = 0.0              # uniform +- fraction of base latency
    max_inflight: int = 0                 # 0 -> unlimited (link BW still caps)
    seed: int = 0

    @classmethod
    def from_latency_us(cls, lat_us: float, freq_ghz: float = 3.0,
                        bandwidth_gbs: float = 64.0, **kw) -> "FarMemoryConfig":
        return cls(base_latency_cycles=lat_us * 1e3 * freq_ghz,
                   bandwidth_bytes_per_cycle=bandwidth_gbs / freq_ghz, **kw)


class FarMemoryModel:
    """Timed far-memory device. All times in core cycles (float)."""

    def __init__(self, config: FarMemoryConfig):
        self.config = config
        self._link_free = 0.0
        self._rng = np.random.default_rng(config.seed)
        self._inflight: List[Tuple[float, int]] = []  # (done_time, token) heap
        self._token = 0
        # stats
        self.requests = 0
        self.bytes_moved = 0
        self.mlp_area = 0.0      # integral of in-flight count over time
        self._last_t = 0.0

    # -- accounting ---------------------------------------------------------
    def _integrate(self, now: float) -> None:
        if now > self._last_t:
            self.mlp_area += len(self._inflight) * (now - self._last_t)
            self._last_t = now

    def inflight_at(self, now: float) -> int:
        while self._inflight and self._inflight[0][0] <= now:
            self._integrate(self._inflight[0][0])
            heapq.heappop(self._inflight)
        return len(self._inflight)

    def avg_mlp(self, total_time: float) -> float:
        self.inflight_at(total_time)
        self._integrate(total_time)
        return self.mlp_area / max(total_time, 1e-9)

    # -- request path -------------------------------------------------------
    def issue(self, now: float, size_bytes: int) -> float:
        """Issue a request at `now`; returns absolute completion time."""
        cfg = self.config
        self.inflight_at(now)
        self._integrate(now)
        inject_at = max(now, self._link_free)
        if cfg.max_inflight and len(self._inflight) >= cfg.max_inflight:
            # device-side queue full: wait for the oldest completion
            oldest = self._inflight[0][0]
            inject_at = max(inject_at, oldest)
            self.inflight_at(inject_at)
            self._integrate(inject_at)
        serial = size_bytes / cfg.bandwidth_bytes_per_cycle
        self._link_free = inject_at + serial
        lat = cfg.base_latency_cycles
        if cfg.jitter_frac:
            lat *= 1.0 + cfg.jitter_frac * float(self._rng.uniform(-1.0, 1.0))
        done = inject_at + serial + lat
        self._token += 1
        heapq.heappush(self._inflight, (done, self._token))
        self.requests += 1
        self.bytes_moved += size_bytes
        return done

    def reset_stats(self) -> None:
        self.requests = 0
        self.bytes_moved = 0
        self.mlp_area = 0.0
        self._last_t = 0.0


class InstantMemory(FarMemoryModel):
    """Zero-latency functional mode (used when the engine is an oracle)."""

    def __init__(self) -> None:
        super().__init__(FarMemoryConfig(base_latency_cycles=0.0,
                                         bandwidth_bytes_per_cycle=float("inf")))

    def issue(self, now: float, size_bytes: int) -> float:
        self.requests += 1
        self.bytes_moved += size_bytes
        return now
