"""Far-memory device model: latency + bandwidth + queueing.

Models the paper's Figure 1/7 memory path: requests leave the core through a
link with finite bandwidth and a base latency that ranges from 0.1 µs (fast
CXL) to 5 µs (cross-switch disaggregated memory). Completion time for a
request issued at `t` is::

    t_done = max(t, link_free) + base_latency + size / bandwidth (+ jitter)

where `link_free` enforces serialization of request injection on the link
(packets inject back-to-back at `size / bandwidth` spacing), giving Little's
law behaviour: sustained MLP on the device cannot exceed
`bandwidth * latency / granularity`.

MLP accounting is closed-form rather than event-driven: since a request is
in flight on [issue, done), the integral of the in-flight count over [0, T]
is exactly ``sum_i(min(done_i, T) - issue_i)``, so the model keeps a flat
ledger of completion times instead of an event heap. A heap exists only in
``max_inflight`` mode, where injection is coupled to completions
(device-side queue backpressure).

The same model backs the functional engine (zero-latency mode), the
cycle-approximate simulator, and the runtime's host-offload tier.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

GHZ = 1e9  # cycles are expressed at the simulated core clock (paper: 3 GHz)


@dataclass
class FarMemoryConfig:
    base_latency_cycles: float = 3000.0   # 1 us at 3 GHz
    bandwidth_bytes_per_cycle: float = 21.3  # ~64 GB/s CXL-ish at 3 GHz
    jitter_frac: float = 0.0              # uniform +- fraction of base latency
    max_inflight: int = 0                 # 0 -> unlimited (link BW still caps)
    seed: int = 0

    @classmethod
    def from_latency_us(cls, lat_us: float, freq_ghz: float = 3.0,
                        bandwidth_gbs: float = 64.0, **kw) -> "FarMemoryConfig":
        return cls(base_latency_cycles=lat_us * 1e3 * freq_ghz,
                   bandwidth_bytes_per_cycle=bandwidth_gbs / freq_ghz, **kw)


class FarMemoryModel:
    """Timed far-memory device. All times in core cycles (float)."""

    def __init__(self, config: FarMemoryConfig):
        self.config = config
        self._link_free = 0.0
        self._rng = np.random.default_rng(config.seed)
        self._token = 0
        # completion-time ledger for closed-form MLP accounting
        self._dones = np.empty(1024, np.float64)
        self._n_done = 0
        self._sum_issue = 0.0
        # event heap, used only in max_inflight (backpressure) mode
        self._inflight: List[Tuple[float, int]] = []
        # stats
        self.requests = 0
        self.bytes_moved = 0

    # -- accounting ---------------------------------------------------------
    def _record(self, issue_t: float, done: float) -> None:
        if self._n_done == self._dones.size:
            self._dones = np.concatenate(
                [self._dones, np.empty(self._dones.size, np.float64)])
        self._dones[self._n_done] = done
        self._n_done += 1
        self._sum_issue += issue_t

    def _record_batch(self, issue_t, done: np.ndarray) -> None:
        """Ledger-record a batch. `issue_t` is a scalar (all requests start
        counting at the same instant) or a per-request array (backpressured
        admission staggers the MSHR-occupancy start times)."""
        need = self._n_done + done.size
        if need > self._dones.size:
            grow = max(self._dones.size * 2, need)
            self._dones = np.concatenate(
                [self._dones[:self._n_done],
                 np.empty(grow - self._n_done, np.float64)])
        self._dones[self._n_done:need] = done
        self._n_done = need
        if np.ndim(issue_t):
            # sequential adds keep the ledger bit-identical to n scalar
            # _record() calls (np.sum's pairwise order differs in float)
            for v in issue_t:
                self._sum_issue += float(v)
        else:
            self._sum_issue += float(issue_t) * done.size

    def inflight_at(self, now: float) -> int:
        """Requests issued at or before `now` that have not completed."""
        if self.config.max_inflight:
            while self._inflight and self._inflight[0][0] <= now:
                heapq.heappop(self._inflight)
            return len(self._inflight)
        return int((self._dones[:self._n_done] > now).sum())

    def avg_mlp(self, total_time: float) -> float:
        area = (float(np.minimum(self._dones[:self._n_done],
                                 total_time).sum()) - self._sum_issue)
        return max(area, 0.0) / max(total_time, 1e-9)

    # -- request path -------------------------------------------------------
    def issue(self, now: float, size_bytes: int) -> float:
        """Issue a request at `now`; returns absolute completion time."""
        cfg = self.config
        inject_at = max(now, self._link_free)
        start = now          # when the request starts counting as in flight
        if cfg.max_inflight and self.inflight_at(now) >= cfg.max_inflight:
            # device-side queue full: wait for the oldest completion; the
            # request only occupies an MSHR (counts toward MLP) from then
            oldest = self._inflight[0][0]
            inject_at = max(inject_at, oldest)
            self.inflight_at(inject_at)
            start = inject_at
        serial = size_bytes / cfg.bandwidth_bytes_per_cycle
        self._link_free = inject_at + serial
        lat = cfg.base_latency_cycles
        if cfg.jitter_frac:
            lat *= 1.0 + cfg.jitter_frac * float(self._rng.uniform(-1.0, 1.0))
        done = inject_at + serial + lat
        if cfg.max_inflight:
            self._token += 1
            heapq.heappush(self._inflight, (done, self._token))
        self._record(start, done)
        self.requests += 1
        self.bytes_moved += size_bytes
        return done

    def issue_batch(self, now: float, sizes: "np.ndarray") -> "np.ndarray":
        """Vectorized :meth:`issue`: n requests injected back-to-back at `now`.

        Trace-identical to n sequential ``issue(now, size)`` calls — link
        serialization is a prefix sum over the per-request injection spacing,
        and jitter draws one length-n uniform vector, which consumes the RNG
        bitstream exactly like n scalar draws.
        """
        sizes = np.asarray(sizes, dtype=np.float64)
        n = sizes.size
        if n == 0:
            return np.empty(0, np.float64)
        cfg = self.config
        if cfg.max_inflight:
            return self._issue_batch_backpressured(now, sizes)
        serial = sizes / cfg.bandwidth_bytes_per_cycle
        inject0 = max(now, self._link_free)
        # cumsum over [inject0, s0, s1, ...] reproduces the scalar loop's
        # left-to-right link_free accumulation bit-for-bit
        injects = np.empty(n, np.float64)
        injects[0] = inject0
        injects[1:] = serial[:-1]
        np.cumsum(injects, out=injects)
        if cfg.jitter_frac:
            lat = cfg.base_latency_cycles * (
                1.0 + cfg.jitter_frac * self._rng.uniform(-1.0, 1.0, size=n))
            done = injects + serial + lat
        else:
            # scalar broadcast == np.full(n, lat) elementwise, bit-for-bit
            done = injects + serial + cfg.base_latency_cycles
        self._link_free = float(injects[-1]) + float(serial[-1])
        self._token += n
        self._record_batch(now, done)
        self.requests += n
        self.bytes_moved += int(sizes.sum())
        return done

    def _issue_batch_backpressured(self, now: float,
                                   sizes: "np.ndarray") -> "np.ndarray":
        """`issue_batch` under ``max_inflight``: chunked admission against the
        completion heap, time-identical to n sequential :meth:`issue` calls.

        The scalar loop admits requests freely while the device queue has
        room (each occupies an MSHR from `now`), then couples injection to
        completions: a backpressured request waits for the oldest in-flight
        completion, and the pop at its injection time may retire *several*
        entries, opening room for another admission burst. We replay exactly
        that alternation, but each admission burst computes its
        link-serialized injection times, jitter draws, and ledger records as
        one vector chunk instead of one Python call per request.
        """
        cfg = self.config
        hp = self._inflight
        n = sizes.size
        serial = sizes / cfg.bandwidth_bytes_per_cycle
        dones = np.empty(n, np.float64)
        starts = np.empty(n, np.float64)
        i = 0
        while i < n:
            # the scalar loop calls inflight_at(now) before every admission
            while hp and hp[0][0] <= now:
                heapq.heappop(hp)
            room = cfg.max_inflight - len(hp)
            if room > 0:
                # admission burst: k requests inject back-to-back from
                # link_free; each counts as in flight from `now`
                k = min(room, n - i)
                chunk = serial[i:i + k]
                inject0 = max(now, self._link_free)
                # same association as the scalar link_free chain (see above)
                injects = np.cumsum(np.concatenate([[inject0], chunk[:-1]]))
                lat = np.full(k, cfg.base_latency_cycles)
                if cfg.jitter_frac:
                    lat *= 1.0 + cfg.jitter_frac * self._rng.uniform(
                        -1.0, 1.0, size=k)
                dk = injects + chunk + lat
                self._link_free = float(injects[-1]) + float(chunk[-1])
                for d in dk:
                    self._token += 1
                    heapq.heappush(hp, (float(d), self._token))
                dones[i:i + k] = dk
                starts[i:i + k] = now
                i += k
            else:
                # queue full: wait for the oldest completion; the pop at the
                # injection time may drain several entries (next loop turn
                # then takes the admission-burst branch)
                inject_at = max(now, self._link_free, hp[0][0])
                while hp and hp[0][0] <= inject_at:
                    heapq.heappop(hp)
                lat = cfg.base_latency_cycles
                if cfg.jitter_frac:
                    lat *= 1.0 + cfg.jitter_frac * float(
                        self._rng.uniform(-1.0, 1.0))
                d = inject_at + float(serial[i]) + lat
                self._link_free = inject_at + float(serial[i])
                self._token += 1
                heapq.heappush(hp, (d, self._token))
                dones[i] = d
                starts[i] = inject_at
                i += 1
        self._record_batch(starts, dones)
        self.requests += n
        self.bytes_moved += int(sizes.sum())
        return dones

    def reset_stats(self) -> None:
        """Zero the request/byte/MLP counters. Requests in flight at the
        reset point stop contributing to MLP (the ledger is cleared)."""
        self.requests = 0
        self.bytes_moved = 0
        self._n_done = 0
        self._sum_issue = 0.0


class InstantMemory(FarMemoryModel):
    """Zero-latency functional mode (used when the engine is an oracle)."""

    def __init__(self) -> None:
        super().__init__(FarMemoryConfig(base_latency_cycles=0.0,
                                         bandwidth_bytes_per_cycle=float("inf")))

    def issue(self, now: float, size_bytes: int) -> float:
        self.requests += 1
        self.bytes_moved += size_bytes
        return now

    def issue_batch(self, now: float, sizes: "np.ndarray") -> "np.ndarray":
        sizes = np.asarray(sizes)
        self.requests += sizes.size
        self.bytes_moved += int(sizes.sum()) if sizes.size else 0
        return np.full(sizes.size, now, np.float64)
