"""Far-memory device model: latency + bandwidth + queueing, heterogeneous.

Models the paper's Figure 1/7 memory path: requests leave the core through a
link with finite bandwidth and a base latency that ranges from 0.1 µs (fast
CXL) to 5 µs (cross-switch disaggregated memory). Completion time for a
request issued at `t` is::

    t_done = max(t, link_free) + base_latency * mult + size / bandwidth

where `link_free` enforces serialization of request injection on the link
(packets inject back-to-back at `size / bandwidth` spacing), giving Little's
law behaviour: sustained MLP on the device cannot exceed
`bandwidth * latency / granularity`; `mult` is a per-request draw from the
configured :class:`LatencyDistribution` (1.0 when none — the paper's point
that far latencies are "longer *and more variable* than local DRAM").

MLP accounting is closed-form rather than event-driven: since a request is
in flight on [issue, done), the integral of the in-flight count over [0, T]
is exactly ``sum_i(min(done_i, T) - issue_i)``, so the model keeps a flat
ledger of completion times instead of an event heap. A heap exists only in
``max_inflight`` mode, where injection is coupled to completions
(device-side queue backpressure).

**Heterogeneous mode** (``FarMemoryConfig.regions``): the address space
splits into per-range :class:`FarMemoryRegion` tiers — e.g. local-DRAM /
fast-CXL / cross-switch — each with its own latency, bandwidth,
``max_inflight``, latency distribution, and *link*. Requests route by
address in :meth:`issue`/:meth:`issue_batch`; regions naming the same
``link`` contend on one serialization point (shared channel) while keeping
their own closed-form MLP ledgers, request/byte counters, RNG streams and
backpressure queues (:meth:`region_stats`). A single region covering the
whole address space is bit-identical to the flat model.

Determinism contract (pinned by tests/test_batched_engine.py and
tests/test_farmem_regions.py): every latency distribution draws through a
seeded ``np.random.Generator`` whose array fills consume the bitstream
exactly like sequential scalar draws, so ``issue_batch`` is bit-identical
to the equivalent ``issue()`` loop — per region, and across regions via
the **mixed-tier reordering path**: when every region a batch touches is
unlimited (no ``max_inflight`` coupling), the scalar loop's cross-region
interleaving factors exactly into independent per-link injection chains
(rows in original order per link) and per-region latency draws (rows in
original order per RNG stream), so an arbitrarily interleaved batch
vectorizes without replaying run boundaries. Batches touching a
backpressured region keep the consecutive same-region run segmentation
(injection there is coupled to completions through a heap).

:meth:`issue_epoch` extends the same factoring across a whole scheduler
epoch of batches ("segments", each with its own issue time): per-link
chains restart their ``max(now, free)`` only at segment boundaries and
per-region draws concatenate, so one entry reproduces the per-command
call sequence bit-for-bit. The sequential recurrences optionally run as
numba kernels (``host_jit=True`` + numba importable, see
:mod:`repro.core.hostjit`) — bit-identical to the numpy fallback.

The same model backs the functional engine (zero-latency mode), the
cycle-approximate simulator, and the runtime's host-offload tier.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import hostjit

GHZ = 1e9  # cycles are expressed at the simulated core clock (paper: 3 GHz)


# =========================================================================
# Latency distributions
# =========================================================================
class LatencyDistribution:
    """A per-request latency *multiplier* draw (1.0 == the base latency).

    Implementations must be seeded-deterministic AND batch/scalar
    bit-identical: ``draw(rng, n)`` consumes the RNG bitstream exactly like
    ``n`` successive ``draw(rng)`` calls (numpy ``Generator`` array fills
    guarantee this for the primitives used here), so the vectorized
    ``issue_batch`` path reproduces the scalar ``issue()`` loop bit-for-bit.
    """

    kind = "none"

    def draw(self, rng: np.random.Generator, n: Optional[int] = None):
        """One multiplier (``n is None``) or a length-``n`` vector."""
        raise NotImplementedError


@dataclass(frozen=True)
class UniformJitter(LatencyDistribution):
    """Uniform ±``frac`` of the base latency — the typed spelling of the
    legacy ``jitter_frac`` knob (identical draws for the same seed)."""

    frac: float = 0.1
    kind = "uniform"

    def draw(self, rng: np.random.Generator, n: Optional[int] = None):
        if n is None:
            return 1.0 + self.frac * float(rng.uniform(-1.0, 1.0))
        return 1.0 + self.frac * rng.uniform(-1.0, 1.0, size=n)


@dataclass(frozen=True)
class LognormalLatency(LatencyDistribution):
    """Mean-preserving lognormal multiplier (``mu = -sigma^2/2``): the
    heavy-ish right tail of real network/far-memory paths with the mean
    latency pinned to the base, so tail sweeps isolate *variability* from
    operating-point shifts."""

    sigma: float = 0.5
    kind = "lognormal"

    def draw(self, rng: np.random.Generator, n: Optional[int] = None):
        mu = -0.5 * self.sigma * self.sigma
        if n is None:
            return float(rng.lognormal(mu, self.sigma))
        return rng.lognormal(mu, self.sigma, size=n)


@dataclass(frozen=True)
class BimodalTail(LatencyDistribution):
    """Bimodal tail: with probability ``tail_prob`` a request pays
    ``tail_mult``× the base latency (retransmits, switch congestion, remote
    NUMA hops); otherwise exactly the base. p50 stays the base latency, p99
    is controlled by (``tail_prob``, ``tail_mult``) — the knob pair behind
    the tail-latency sweep in benchmarks/paper_figures.py."""

    tail_prob: float = 0.05
    tail_mult: float = 8.0
    kind = "bimodal"

    def draw(self, rng: np.random.Generator, n: Optional[int] = None):
        if n is None:
            return self.tail_mult if float(rng.random()) < self.tail_prob \
                else 1.0
        u = rng.random(size=n)
        return np.where(u < self.tail_prob, self.tail_mult, 1.0)


# =========================================================================
# Fault plane
# =========================================================================
#: Per-request completion status codes carried out-of-band with every done
#: time (``FarMemoryModel.last_status`` / ``last_statuses``) and through the
#: engines' AMART into the scheduler. OK requests move data; ERROR is a
#: device NACK arriving at the normal completion time; TIMED_OUT is a
#: dropped request whose failure notice surfaces after ``timeout_mult``×
#: the base latency (or at the RetryPolicy's ``timeout_cycles`` bound).
STATUS_OK = 0
STATUS_ERROR = 1
STATUS_TIMED_OUT = 2


@dataclass(frozen=True)
class LinkFlap:
    """A transient outage window on a region's channel, in absolute core
    cycles. Requests *injected* inside ``[start_cycle, start_cycle +
    duration)`` are affected: ``mode="stall"`` holds their delivery in the
    channel's retry buffer until the window clears (completion shifts by
    the remaining outage; injection pipelining of later requests is
    unaffected, keeping the fault plane orthogonal to the pinned
    link-serialization chains), ``mode="error"`` NACKs them at their normal
    completion time."""

    start_cycle: float
    duration: float
    mode: str = "stall"

    @property
    def end(self) -> float:
        return self.start_cycle + self.duration


@dataclass(frozen=True)
class FaultModel:
    """Seeded per-region fault injection. Each request draws exactly one
    uniform from the region's dedicated fault stream (spawned from the
    region's RNG lineage, so the latency bitstream is untouched and batch
    fills equal sequential scalar draws): ``u < error_prob`` → ERROR,
    next ``drop_prob`` mass → TIMED_OUT (dropped; failure notice at
    ``timeout_mult``× base latency). ``flaps`` adds deterministic outage
    windows on top (no RNG). A region with no FaultModel draws nothing —
    zero-fault configs execute today's code paths bit-for-bit."""

    error_prob: float = 0.0
    drop_prob: float = 0.0
    timeout_mult: float = 8.0
    flaps: Tuple[LinkFlap, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "flaps", tuple(self.flaps))


def _validate_fault_model(fm: FaultModel, where: str) -> None:
    if fm.error_prob < 0.0 or fm.drop_prob < 0.0:
        raise ValueError(f"{where}: fault probabilities must be >= 0, got "
                         f"error_prob={fm.error_prob}, "
                         f"drop_prob={fm.drop_prob}")
    if fm.error_prob + fm.drop_prob > 1.0:
        raise ValueError(f"{where}: error_prob + drop_prob must be <= 1, "
                         f"got {fm.error_prob + fm.drop_prob}")
    if fm.timeout_mult <= 0.0:
        raise ValueError(f"{where}: timeout_mult must be > 0, got "
                         f"{fm.timeout_mult}")
    prev_end = None
    prev_name = None
    for fl in sorted(fm.flaps, key=lambda f: f.start_cycle):
        if fl.start_cycle < 0.0 or fl.duration <= 0.0:
            raise ValueError(f"{where}: LinkFlap needs start_cycle >= 0 and "
                             f"duration > 0, got start={fl.start_cycle}, "
                             f"duration={fl.duration}")
        if fl.mode not in ("stall", "error"):
            raise ValueError(f"{where}: LinkFlap mode must be 'stall' or "
                             f"'error', got {fl.mode!r}")
        if prev_end is not None and fl.start_cycle < prev_end:
            raise ValueError(f"{where}: overlapping outage windows "
                             f"[{fl.start_cycle}, {fl.end}) and "
                             f"{prev_name}; merge them")
        prev_end = fl.end
        prev_name = f"[{fl.start_cycle}, {fl.end})"


# =========================================================================
# Regions
# =========================================================================
@dataclass(frozen=True)
class FarMemoryRegion:
    """One address-range tier of a heterogeneous far memory.

    ``[start, start + size)`` is the far-memory address range served at this
    operating point. ``link`` names the injection channel: regions sharing a
    link name contend on one serialization point (shared channel);
    ``link=None`` gives the region a private link named after it. Requests
    must not straddle a region boundary (routed by start address, validated
    against the end — a straddle raises rather than silently misroutes).
    """

    name: str
    start: int
    size: int
    base_latency_cycles: float
    bandwidth_bytes_per_cycle: float = 21.3
    max_inflight: int = 0                 # 0 -> unlimited (link BW still caps)
    jitter_frac: float = 0.0              # legacy uniform ± fraction
    distribution: Optional[LatencyDistribution] = None
    link: Optional[str] = None
    faults: Optional[FaultModel] = None   # None -> this region never fails
    #: name of the region retry-exhausted requests re-route to (same far
    #: address, alternate path/replica): the scheduler's degradation mode.
    failover: Optional[str] = None

    @property
    def end(self) -> int:
        return self.start + self.size

    @classmethod
    def from_latency_us(cls, name: str, start: int, size: int,
                        lat_us: float, freq_ghz: float = 3.0,
                        bandwidth_gbs: float = 64.0, **kw) -> "FarMemoryRegion":
        return cls(name, start, size,
                   base_latency_cycles=lat_us * 1e3 * freq_ghz,
                   bandwidth_bytes_per_cycle=bandwidth_gbs / freq_ghz, **kw)


def _validate_regions(regions: Tuple[FarMemoryRegion, ...]) -> None:
    names = [r.name for r in regions]
    if len(set(names)) != len(names) or not all(names):
        raise ValueError(f"region names must be unique and non-empty: {names}")
    prev_end = None
    for r in regions:
        if r.size <= 0 or r.start < 0:
            raise ValueError(f"region {r.name!r}: need start >= 0 and "
                             f"size > 0, got [{r.start}, {r.end})")
        if r.base_latency_cycles < 0 or r.bandwidth_bytes_per_cycle <= 0:
            raise ValueError(f"region {r.name!r}: latency must be >= 0 and "
                             f"bandwidth > 0")
        if r.max_inflight < 0:
            raise ValueError(f"region {r.name!r}: max_inflight must be >= 0")
        if r.jitter_frac and r.distribution is not None:
            raise ValueError(f"region {r.name!r}: jitter_frac and "
                             f"distribution are two spellings of the same "
                             f"knob; set one")
        if prev_end is not None and r.start < prev_end:
            raise ValueError(f"regions must be ascending and non-overlapping;"
                             f" {r.name!r} starts at {r.start} before the "
                             f"previous region ends at {prev_end}")
        prev_end = r.end
        if r.faults is not None:
            _validate_fault_model(r.faults, f"region {r.name!r}")
    by_name = {r.name: r for r in regions}
    for r in regions:
        if r.failover is None:
            continue
        if r.failover == r.name:
            raise ValueError(f"region {r.name!r} fails over to itself")
        if r.failover not in by_name:
            raise ValueError(f"region {r.name!r} fails over to unknown "
                             f"region {r.failover!r} (have {names})")
        seen = [r.name]
        cur = r
        while cur.failover is not None:
            if cur.failover in seen:
                raise ValueError(
                    f"failover cycle: {' -> '.join(seen)} -> {cur.failover}")
            seen.append(cur.failover)
            cur = by_name[cur.failover]


@dataclass
class FarMemoryConfig:
    base_latency_cycles: float = 3000.0   # 1 us at 3 GHz
    bandwidth_bytes_per_cycle: float = 21.3  # ~64 GB/s CXL-ish at 3 GHz
    jitter_frac: float = 0.0              # uniform +- fraction of base latency
    max_inflight: int = 0                 # 0 -> unlimited (link BW still caps)
    seed: int = 0
    distribution: Optional[LatencyDistribution] = None
    #: heterogeneous mode: per-address-range tiers (empty -> flat model).
    #: The flat operating-point fields above are ignored when regions are
    #: set; each region carries its own. Region i draws from
    #: ``default_rng(seed + i)``, so a single region covering the address
    #: space reproduces the flat model bit-for-bit.
    regions: Tuple[FarMemoryRegion, ...] = ()
    #: flat-model fault injection (heterogeneous mode attaches a FaultModel
    #: per region instead).
    faults: Optional[FaultModel] = None

    def __post_init__(self) -> None:
        self.regions = tuple(self.regions)
        if self.regions:
            _validate_regions(self.regions)
            if self.faults is not None:
                raise ValueError("heterogeneous far memory takes faults per "
                                 "region (FarMemoryRegion.faults), not on "
                                 "the config")
        elif self.faults is not None:
            _validate_fault_model(self.faults, "far memory")
        if self.jitter_frac and self.distribution is not None:
            raise ValueError("jitter_frac and distribution are two spellings "
                             "of the same knob; set one")

    @classmethod
    def from_latency_us(cls, lat_us: float, freq_ghz: float = 3.0,
                        bandwidth_gbs: float = 64.0, **kw) -> "FarMemoryConfig":
        return cls(base_latency_cycles=lat_us * 1e3 * freq_ghz,
                   bandwidth_bytes_per_cycle=bandwidth_gbs / freq_ghz, **kw)


# =========================================================================
# Internal state helpers
# =========================================================================
class _Ledger:
    """Closed-form MLP ledger: completion times + sum of issue times.

    ``seq_sum`` optionally points at the jitted sequential accumulator
    (``host_jit``): same left-to-right binary adds, same bits.
    """

    __slots__ = ("dones", "n", "sum_issue", "seq_sum")

    def __init__(self, seq_sum=None) -> None:
        self.dones = np.empty(1024, np.float64)
        self.n = 0
        self.sum_issue = 0.0
        self.seq_sum = seq_sum

    def record(self, issue_t: float, done: float) -> None:
        if self.n == self.dones.size:
            self.dones = np.concatenate(
                [self.dones, np.empty(self.dones.size, np.float64)])
        self.dones[self.n] = done
        self.n += 1
        self.sum_issue += issue_t

    def record_batch(self, issue_t, done: np.ndarray) -> None:
        """Ledger-record a batch. `issue_t` is a scalar (all requests start
        counting at the same instant) or a per-request array (backpressured
        admission staggers the MSHR-occupancy start times)."""
        need = self.n + done.size
        if need > self.dones.size:
            grow = max(self.dones.size * 2, need)
            self.dones = np.concatenate(
                [self.dones[:self.n], np.empty(grow - self.n, np.float64)])
        self.dones[self.n:need] = done
        self.n = need
        if np.ndim(issue_t):
            # sequential adds keep the ledger bit-identical to n scalar
            # record() calls (np.sum's pairwise order differs in float)
            if self.seq_sum is not None:
                self.sum_issue = float(self.seq_sum(
                    np.asarray(issue_t, np.float64), self.sum_issue))
            else:
                for v in issue_t:
                    self.sum_issue += float(v)
        else:
            self.sum_issue += float(issue_t) * done.size

    def area(self, total_time: float) -> float:
        """Integral of the in-flight count over [0, total_time]."""
        a = (float(np.minimum(self.dones[:self.n], total_time).sum())
             - self.sum_issue)
        return max(a, 0.0)

    def inflight(self, now: float) -> int:
        return int((self.dones[:self.n] > now).sum())

    def clear(self) -> None:
        self.n = 0
        self.sum_issue = 0.0


class _Link:
    """A serialization point: the time the channel next becomes free.
    Regions sharing a link share one of these (shared-channel contention)."""

    __slots__ = ("free",)

    def __init__(self) -> None:
        self.free = 0.0


class _RegionState:
    """Mutable per-region runtime state (the flat model's fields, per tier)."""

    __slots__ = ("region", "link", "rng", "token", "inflight", "ledger",
                 "requests", "bytes_moved", "fault_rng", "errors", "timeouts",
                 "stalls")

    def __init__(self, region: FarMemoryRegion, link: _Link,
                 rng: np.random.Generator, seq_sum=None) -> None:
        self.region = region
        self.link = link
        self.rng = rng
        self.token = 0
        self.inflight: List[Tuple[float, int]] = []
        self.ledger = _Ledger(seq_sum)
        self.requests = 0
        self.bytes_moved = 0
        # dedicated fault stream, spawned from the region's RNG lineage:
        # deterministic per seed, and drawing from it never advances the
        # latency bitstream (zero-fault configs stay bit-identical)
        self.fault_rng = rng.spawn(1)[0] if region.faults is not None else None
        self.errors = 0
        self.timeouts = 0
        self.stalls = 0


class FarMemoryModel:
    """Timed far-memory device. All times in core cycles (float).

    ``host_jit=True`` swaps the sequential injection-chain / ledger
    recurrences for numba kernels when numba is importable (pure-numpy
    fallback otherwise) — results are bit-identical either way.
    """

    def __init__(self, config: FarMemoryConfig, host_jit: bool = False,
                 timeout_cycles: float = 0.0):
        self.config = config
        self.host_jit = bool(host_jit)
        self._jit_chain = hostjit.get_chain(self.host_jit)
        seq_sum = hostjit.get_seq_sum(self.host_jit)
        self._link_free = 0.0
        self._rng = np.random.default_rng(config.seed)
        self._token = 0
        self._ledger = _Ledger(seq_sum)
        # event heap, used only in max_inflight (backpressure) mode
        self._inflight: List[Tuple[float, int]] = []
        # stats
        self.requests = 0
        self.bytes_moved = 0
        # shared-device occupancy attribution: `client` tags the requester
        # currently issuing (the rack arbiter sets it to the core index
        # before stepping each core; single-core sessions leave it at 0)
        # and `link_busy` accumulates serialized channel cycles per
        # (link, client). Pure accounting — never feeds timing or RNG, so
        # traces/bitstreams are untouched by who (or whether anyone) reads
        # it. The flat (regionless) model charges one implicit "far" link.
        self.client = 0
        self.link_busy: Dict[str, Dict[int, float]] = {}
        # fault plane: requester-side timeout bound (RetryPolicy), flat-model
        # fault stream, counters, and the out-of-band status channel the
        # engines read right after each issue call. When fault_enabled is
        # False every fault branch below is skipped — zero-fault configs run
        # exactly the pre-fault code (bit-identical traces and bitstreams).
        self.timeout_cycles = float(timeout_cycles)
        self.fault_enabled = bool(
            self.timeout_cycles > 0.0
            or config.faults is not None
            or any(r.faults is not None for r in config.regions))
        self._fault_rng = (self._rng.spawn(1)[0]
                           if config.faults is not None else None)
        self._forced_region: Optional[int] = None   # failover route override
        self.errors = 0
        self.timeouts = 0
        self.stalls = 0
        self.last_status = STATUS_OK        # after issue(), in fault mode
        self.last_statuses: Optional[np.ndarray] = None  # after batch/epoch
        # heterogeneous mode: per-region state + address-routing arrays
        self._regions: Optional[List[_RegionState]] = None
        if config.regions:
            links: Dict[str, _Link] = {}
            self._regions = [
                _RegionState(r, links.setdefault(r.link or r.name, _Link()),
                             np.random.default_rng(config.seed + i), seq_sum)
                for i, r in enumerate(config.regions)]
            self._starts = np.array([r.start for r in config.regions],
                                    np.int64)
            self._ends = np.array([r.end for r in config.regions], np.int64)
            # reordering-path tables: per-region bandwidth / backpressure
            # flags and a dense link index (regions sharing a _Link share an
            # index), so a mixed batch routes to per-link chains without
            # touching Python objects per row
            self._links: List[_Link] = []
            link_ix: Dict[int, int] = {}
            lt = []
            for st in self._regions:
                ix = link_ix.setdefault(id(st.link), len(self._links))
                if ix == len(self._links):
                    self._links.append(st.link)
                lt.append(ix)
            self._link_table = np.array(lt, np.int64)
            self._bw_table = np.array(
                [r.bandwidth_bytes_per_cycle for r in config.regions],
                np.float64)
            self._mi_table = np.array(
                [r.max_inflight for r in config.regions], np.int64)

    # -- accounting ---------------------------------------------------------
    def inflight_at(self, now: float) -> int:
        """Requests issued at or before `now` that have not completed."""
        if self._regions is not None:
            return sum(self._region_inflight_at(st, now)
                       for st in self._regions)
        if self.config.max_inflight:
            while self._inflight and self._inflight[0][0] <= now:
                heapq.heappop(self._inflight)
            return len(self._inflight)
        return self._ledger.inflight(now)

    def avg_mlp(self, total_time: float) -> float:
        if self._regions is not None:
            area = sum(st.ledger.area(total_time) for st in self._regions)
        else:
            area = self._ledger.area(total_time)
        return area / max(total_time, 1e-9)

    def _charge_link(self, link: str, serial_cycles: float) -> None:
        by = self.link_busy.setdefault(link, {})
        by[self.client] = by.get(self.client, 0.0) + serial_cycles

    def link_occupancy(self, total_time: float) -> Dict[str, Dict]:
        """Per-link serialized-cycle totals and busy fraction over
        ``[0, total_time]``, with the per-client split (`by_client` keys are
        the requester tags — rack core indices). ``occupancy`` near 1.0
        means the channel itself is the bottleneck."""
        return {
            link: {
                "busy_cycles": sum(by.values()),
                "occupancy": sum(by.values()) / max(total_time, 1e-9),
                "by_client": dict(sorted(by.items())),
            } for link, by in sorted(self.link_busy.items())}

    def region_stats(self, total_time: float) -> Optional[Dict[str, Dict]]:
        """Per-region request/byte/MLP stats (None for the flat model)."""
        if self._regions is None:
            return None
        return {
            st.region.name: {
                "requests": st.requests,
                "bytes": st.bytes_moved,
                "mlp": st.ledger.area(total_time) / max(total_time, 1e-9),
                "latency_cycles": st.region.base_latency_cycles,
                "link": st.region.link or st.region.name,
                **({"errors": st.errors, "timeouts": st.timeouts}
                   if self.fault_enabled else {}),
            } for st in self._regions}

    # -- fault plane --------------------------------------------------------
    @property
    def faults_injected(self) -> int:
        return self.errors + self.timeouts

    def failover_index(self, addr: int) -> Optional[int]:
        """Region index the scheduler re-routes `addr` to after retry
        exhaustion (None when addr's home region has no failover)."""
        if self._regions is None:
            return None
        home = self._route(int(addr), 0)
        if home.region.failover is None:
            return None
        for i, st in enumerate(self._regions):
            if st.region.name == home.region.failover:
                return i
        return None

    def _fault_active(self, faults: Optional[FaultModel]) -> bool:
        return faults is not None or self.timeout_cycles > 0.0

    def _apply_faults(self, st: Optional[_RegionState], starts, injects,
                      serial, done):
        """Classify one chunk of requests and apply fault timing overrides.

        ``st`` is the owning region state (None for the flat model). Consumes
        exactly one uniform per request from the fault stream when the chunk
        carries fault probabilities — a stream separate from the latency
        stream, filled per chunk exactly like sequential scalar draws, so
        every existing bitstream identity survives. ERROR keeps normal
        timing (a NACK rides the response path); dropped requests surface
        TIMED_OUT at ``timeout_mult``× base latency; stall-flap windows
        defer delivery to the outage end; the requester-side
        ``timeout_cycles`` bound reclassifies anything slower than ``start +
        timeout_cycles``. Returns ``(done, status)`` — done possibly
        rewritten, status int8 per request. Link-free evolution is computed
        by the callers *before* this runs, so faults never perturb the
        pinned injection chains."""
        faults = st.region.faults if st is not None else self.config.faults
        n = done.size
        status = np.zeros(n, np.int8)
        if faults is not None:
            frng = st.fault_rng if st is not None else self._fault_rng
            psum = faults.error_prob + faults.drop_prob
            if psum > 0.0:
                u = frng.random(size=n)
                err = u < faults.error_prob
                drop = ~err & (u < psum)
                if err.any():
                    status[err] = STATUS_ERROR
                if drop.any():
                    status[drop] = STATUS_TIMED_OUT
                    done = np.where(
                        drop,
                        injects + serial + (st.region.base_latency_cycles
                                            if st is not None else
                                            self.config.base_latency_cycles)
                        * faults.timeout_mult,
                        done)
            for fl in faults.flaps:
                inwin = (injects >= fl.start_cycle) & (injects < fl.end)
                if not inwin.any():
                    continue
                hit = inwin & (status == STATUS_OK)
                if fl.mode == "error":
                    status[hit] = STATUS_ERROR
                else:       # stall: held in the retry buffer until it clears
                    done = np.where(hit, fl.end + (done - injects), done)
                    ns = int(hit.sum())
                    self.stalls += ns
                    if st is not None:
                        st.stalls += ns
        if self.timeout_cycles > 0.0:
            late = (status == STATUS_OK) \
                & (done - starts > self.timeout_cycles)
            if late.any():
                status[late] = STATUS_TIMED_OUT
                done = np.where(late, starts + self.timeout_cycles, done)
        ne = int((status == STATUS_ERROR).sum())
        nt = int((status == STATUS_TIMED_OUT).sum())
        if ne or nt:
            self.errors += ne
            self.timeouts += nt
            if st is not None:
                st.errors += ne
                st.timeouts += nt
        return done, status

    # -- request path -------------------------------------------------------
    def issue(self, now: float, size_bytes: int,
              addr: Optional[int] = None) -> float:
        """Issue a request at `now`; returns absolute completion time.
        `addr` routes to the owning region in heterogeneous mode (ignored
        by the flat model)."""
        if self._regions is not None:
            return self._region_issue(self._route(addr, size_bytes),
                                      now, size_bytes)
        cfg = self.config
        inject_at = max(now, self._link_free)
        start = now          # when the request starts counting as in flight
        if cfg.max_inflight and self.inflight_at(now) >= cfg.max_inflight:
            # device-side queue full: wait for the oldest completion; the
            # request only occupies an MSHR (counts toward MLP) from then
            oldest = self._inflight[0][0]
            inject_at = max(inject_at, oldest)
            self.inflight_at(inject_at)
            start = inject_at
        serial = size_bytes / cfg.bandwidth_bytes_per_cycle
        self._link_free = inject_at + serial
        self._charge_link("far", serial)
        lat = cfg.base_latency_cycles
        if cfg.distribution is not None:
            lat *= cfg.distribution.draw(self._rng)
        elif cfg.jitter_frac:
            lat *= 1.0 + cfg.jitter_frac * float(self._rng.uniform(-1.0, 1.0))
        done = inject_at + serial + lat
        if self.fault_enabled:
            d1, s1 = self._apply_faults(None, start, np.array([inject_at]),
                                        np.array([serial]), np.array([done]))
            done = float(d1[0])
            self.last_status = int(s1[0])
        if cfg.max_inflight:
            self._token += 1
            heapq.heappush(self._inflight, (done, self._token))
        self._ledger.record(start, done)
        self.requests += 1
        self.bytes_moved += size_bytes
        return done

    def issue_batch(self, now: float, sizes: "np.ndarray",
                    addrs: Optional["np.ndarray"] = None) -> "np.ndarray":
        """Vectorized :meth:`issue`: n requests injected back-to-back at `now`.

        Trace-identical to n sequential ``issue(now, size, addr)`` calls —
        link serialization is a prefix sum over the per-request injection
        spacing, and latency draws consume each RNG bitstream exactly like n
        scalar draws. In heterogeneous mode the batch is processed as
        consecutive same-region runs (each vectorized against its region's
        link/RNG), which reproduces the scalar loop's cross-region link and
        RNG interleaving bit-for-bit.
        """
        sizes = np.asarray(sizes, dtype=np.float64)
        n = sizes.size
        if n == 0:
            return np.empty(0, np.float64)
        status = np.zeros(n, np.int8) if self.fault_enabled else None
        if status is not None:
            self.last_statuses = status
        if self._regions is not None:
            return self._region_issue_batch_routed(now, sizes, addrs, status)
        cfg = self.config
        if cfg.max_inflight:
            return self._issue_batch_backpressured(now, sizes, status)
        serial = sizes / cfg.bandwidth_bytes_per_cycle
        inject0 = max(now, self._link_free)
        # cumsum over [inject0, s0, s1, ...] reproduces the scalar loop's
        # left-to-right link_free accumulation bit-for-bit
        injects = np.empty(n, np.float64)
        injects[0] = inject0
        injects[1:] = serial[:-1]
        np.cumsum(injects, out=injects)
        if cfg.distribution is not None:
            lat = cfg.base_latency_cycles * cfg.distribution.draw(self._rng, n)
            done = injects + serial + lat
        elif cfg.jitter_frac:
            lat = cfg.base_latency_cycles * (
                1.0 + cfg.jitter_frac * self._rng.uniform(-1.0, 1.0, size=n))
            done = injects + serial + lat
        else:
            # scalar broadcast == np.full(n, lat) elementwise, bit-for-bit
            done = injects + serial + cfg.base_latency_cycles
        self._link_free = float(injects[-1]) + float(serial[-1])
        self._charge_link("far", float(serial.sum()))
        if status is not None:
            done, status[:] = self._apply_faults(None, now, injects, serial,
                                                 done)
        self._ledger.record_batch(now, done)
        self.requests += n
        self.bytes_moved += int(sizes.sum())
        return done

    def _issue_batch_backpressured(self, now: float, sizes: "np.ndarray",
                                   status_out=None) -> "np.ndarray":
        """`issue_batch` under ``max_inflight``: chunked admission against the
        completion heap, time-identical to n sequential :meth:`issue` calls.

        The scalar loop admits requests freely while the device queue has
        room (each occupies an MSHR from `now`), then couples injection to
        completions: a backpressured request waits for the oldest in-flight
        completion, and the pop at its injection time may retire *several*
        entries, opening room for another admission burst. We replay exactly
        that alternation, but each admission burst computes its
        link-serialized injection times, latency draws, and ledger records
        as one vector chunk instead of one Python call per request.
        """
        cfg = self.config
        hp = self._inflight
        n = sizes.size
        serial = sizes / cfg.bandwidth_bytes_per_cycle
        dones = np.empty(n, np.float64)
        starts = np.empty(n, np.float64)
        i = 0
        while i < n:
            # the scalar loop calls inflight_at(now) before every admission
            while hp and hp[0][0] <= now:
                heapq.heappop(hp)
            room = cfg.max_inflight - len(hp)
            if room > 0:
                # admission burst: k requests inject back-to-back from
                # link_free; each counts as in flight from `now`
                k = min(room, n - i)
                chunk = serial[i:i + k]
                inject0 = max(now, self._link_free)
                # same association as the scalar link_free chain (see above)
                injects = np.cumsum(np.concatenate([[inject0], chunk[:-1]]))
                lat = np.full(k, cfg.base_latency_cycles)
                if cfg.distribution is not None:
                    lat = lat * cfg.distribution.draw(self._rng, k)
                elif cfg.jitter_frac:
                    lat *= 1.0 + cfg.jitter_frac * self._rng.uniform(
                        -1.0, 1.0, size=k)
                dk = injects + chunk + lat
                self._link_free = float(injects[-1]) + float(chunk[-1])
                if status_out is not None:
                    dk, status_out[i:i + k] = self._apply_faults(
                        None, now, injects, chunk, dk)
                for d in dk:
                    self._token += 1
                    heapq.heappush(hp, (float(d), self._token))
                dones[i:i + k] = dk
                starts[i:i + k] = now
                i += k
            else:
                # queue full: wait for the oldest completion; the pop at the
                # injection time may drain several entries (next loop turn
                # then takes the admission-burst branch)
                inject_at = max(now, self._link_free, hp[0][0])
                while hp and hp[0][0] <= inject_at:
                    heapq.heappop(hp)
                lat = cfg.base_latency_cycles
                if cfg.distribution is not None:
                    lat *= cfg.distribution.draw(self._rng)
                elif cfg.jitter_frac:
                    lat *= 1.0 + cfg.jitter_frac * float(
                        self._rng.uniform(-1.0, 1.0))
                d = inject_at + float(serial[i]) + lat
                self._link_free = inject_at + float(serial[i])
                if status_out is not None:
                    d1, s1 = self._apply_faults(
                        None, inject_at, np.array([inject_at]),
                        np.array([float(serial[i])]), np.array([d]))
                    d = float(d1[0])
                    status_out[i] = s1[0]
                self._token += 1
                heapq.heappush(hp, (d, self._token))
                dones[i] = d
                starts[i] = inject_at
                i += 1
        self._charge_link("far", float(serial.sum()))
        self._ledger.record_batch(starts, dones)
        self.requests += n
        self.bytes_moved += int(sizes.sum())
        return dones

    # -- heterogeneous (regioned) request path ------------------------------
    def _route(self, addr: Optional[int], size: int) -> _RegionState:
        if self._forced_region is not None:
            # failover re-issue: alternate path/replica serving the same far
            # address — range checks are the home region's concern
            return self._regions[self._forced_region]
        if addr is None:
            raise ValueError("heterogeneous far memory routes by address; "
                             "issue() needs addr")
        i = int(np.searchsorted(self._starts, addr, side="right")) - 1
        if i < 0 or addr >= self._ends[i]:
            raise ValueError(f"address {addr} outside configured far-memory "
                             f"regions")
        if addr + size > self._ends[i]:
            r = self._regions[i].region
            raise ValueError(f"request [{addr}, {addr + size}) straddles "
                             f"region {r.name!r} ending at {r.end}")
        return self._regions[i]

    def _region_inflight_at(self, st: _RegionState, now: float) -> int:
        if st.region.max_inflight:
            while st.inflight and st.inflight[0][0] <= now:
                heapq.heappop(st.inflight)
            return len(st.inflight)
        return st.ledger.inflight(now)

    def _region_lat(self, st: _RegionState, n: Optional[int] = None):
        """Latency draw(s) for one region — scalar/batch bit-identical."""
        r = st.region
        lat = r.base_latency_cycles
        if r.distribution is not None:
            return lat * r.distribution.draw(st.rng, n)
        if r.jitter_frac:
            if n is None:
                return lat * (1.0 + r.jitter_frac
                              * float(st.rng.uniform(-1.0, 1.0)))
            return lat * (1.0 + r.jitter_frac
                          * st.rng.uniform(-1.0, 1.0, size=n))
        return lat if n is None else np.full(n, lat)

    def _region_issue(self, st: _RegionState, now: float, size: int) -> float:
        r = st.region
        inject_at = max(now, st.link.free)
        start = now
        if r.max_inflight and self._region_inflight_at(st, now) \
                >= r.max_inflight:
            oldest = st.inflight[0][0]
            inject_at = max(inject_at, oldest)
            self._region_inflight_at(st, inject_at)
            start = inject_at
        serial = size / r.bandwidth_bytes_per_cycle
        st.link.free = inject_at + serial
        self._charge_link(r.link or r.name, serial)
        done = inject_at + serial + self._region_lat(st)
        if self.fault_enabled:
            if self._fault_active(r.faults):
                d1, s1 = self._apply_faults(
                    st, start, np.array([inject_at]), np.array([serial]),
                    np.array([done]))
                done = float(d1[0])
                self.last_status = int(s1[0])
            else:
                self.last_status = STATUS_OK
        if r.max_inflight:
            st.token += 1
            heapq.heappush(st.inflight, (done, st.token))
        st.ledger.record(start, done)
        st.requests += 1
        st.bytes_moved += size
        self.requests += 1
        self.bytes_moved += size
        return done

    def _route_batch(self, sizes: np.ndarray, addrs) -> np.ndarray:
        """Vectorized routing + validation: region index per row."""
        if addrs is None:
            raise ValueError("heterogeneous far memory routes by address; "
                             "issue_batch() needs addrs")
        addrs = np.asarray(addrs, np.int64)
        idx = np.searchsorted(self._starts, addrs, side="right") - 1
        safe = np.clip(idx, 0, len(self._regions) - 1)
        bad = ((idx < 0) | (addrs >= self._ends[safe])
               | (addrs + sizes.astype(np.int64) > self._ends[safe]))
        if bad.any():
            # re-raise through the scalar validator for the precise message
            b = int(np.argmax(bad))
            self._route(int(addrs[b]), int(sizes[b]))
        return idx

    def _region_issue_batch_routed(self, now: float, sizes: np.ndarray,
                                   addrs, status_out=None) -> np.ndarray:
        idx = self._route_batch(sizes, addrs)
        n = sizes.size
        involved = np.unique(idx)
        if involved.size > 1 and not self._mi_table[involved].any():
            # mixed-tier reordering path: arbitrary interleavings of
            # unlimited regions vectorize as per-link chains + per-region
            # draws (bit-identical to the scalar loop; see issue_epoch)
            return self._fused_routed(np.array([now], np.float64),
                                      np.array([0, n], np.int64), sizes, idx,
                                      status_out)
        dones = np.empty(n, np.float64)
        i = 0
        while i < n:                    # consecutive same-region runs
            j = i + 1
            while j < n and idx[j] == idx[i]:
                j += 1
            st = self._regions[int(idx[i])]
            sub = status_out[i:j] if status_out is not None else None
            if st.region.max_inflight:
                dones[i:j] = self._region_batch_backpressured(
                    st, now, sizes[i:j], sub)
            else:
                dones[i:j] = self._region_batch(st, now, sizes[i:j], sub)
            i = j
        return dones

    def _chain_inject(self, seg_nows, seg_bounds, serial, link_ids,
                      free) -> np.ndarray:
        """Per-link injection chains across segments, in row order.

        ``free`` is a float64 array of per-link next-free times, updated in
        place. Bit-identical to the scalar per-row recurrence
        ``inj = max(now_seg(i), free[l_i]); free[l_i] = inj + serial_i``:
        within one (segment, link) chunk the link's free time can only stay
        at/above that segment's `now` after the first row, so the inner rows
        collapse to the same left-to-right ``np.cumsum`` the single-region
        batch path uses. The jitted kernel runs the recurrence directly —
        same sequential binary ops, same bits.
        """
        n = serial.size
        injects = np.empty(n, np.float64)
        if self._jit_chain is not None:
            nows_row = np.repeat(seg_nows, np.diff(seg_bounds))
            self._jit_chain(nows_row, serial, link_ids, free, injects)
            return injects
        if free.size == 1:
            # single link (flat model, or all regions on one channel): the
            # per-link grouping is the identity, so each segment is one
            # contiguous cumsum chunk
            f = float(free[0])
            for s in range(seg_nows.size):
                lo, hi = int(seg_bounds[s]), int(seg_bounds[s + 1])
                if lo == hi:
                    continue
                inj = injects[lo:hi]
                inj[0] = max(float(seg_nows[s]), f)
                inj[1:] = serial[lo:hi - 1]
                np.cumsum(inj, out=inj)
                f = float(inj[-1]) + float(serial[hi - 1])
            free[0] = f
            return injects
        for s in range(seg_nows.size):
            lo, hi = int(seg_bounds[s]), int(seg_bounds[s + 1])
            if lo == hi:
                continue
            now_s = float(seg_nows[s])
            seg_links = link_ids[lo:hi]
            for ix in np.unique(seg_links):
                rows = lo + np.flatnonzero(seg_links == ix)
                ser = serial[rows]
                inj = np.empty(rows.size, np.float64)
                inj[0] = max(now_s, float(free[ix]))
                inj[1:] = ser[:-1]
                np.cumsum(inj, out=inj)
                injects[rows] = inj
                free[ix] = float(inj[-1]) + float(ser[-1])
        return injects

    def _fused_routed_small(self, seg_nows, seg_bounds, sizes,
                            idx, status_out=None) -> np.ndarray:
        """`_fused_routed` for a handful of rows (serving epochs under
        open-loop arrivals carry ~4): the same factoring run as Python
        loops, skipping the unique/flatnonzero machinery whose fixed cost
        dominates at this scale. Bit-identical — draws happen in the same
        ascending-region order with the same chunk counts, the per-link
        injection recurrence is the same sequence of float ops the cumsum
        chunks reduce to, and ledger/stat chunks keep the per-(segment,
        region) association."""
        n = sizes.size
        il = idx.tolist()
        serial = sizes / self._bw_table[idx]
        lat = np.empty(n, np.float64)
        for ri in sorted(set(il)):
            rows = [i for i, r in enumerate(il) if r == ri]
            lat[rows] = self._region_lat(self._regions[ri], len(rows))
            r = self._regions[ri].region
            self._charge_link(r.link or r.name, float(serial[rows].sum()))
        links = self._link_table[idx].tolist()
        free = {ix: float(l.free) for ix, l in enumerate(self._links)}
        injects = np.empty(n, np.float64)
        bounds = seg_bounds.tolist()
        nows = seg_nows.tolist()
        for s in range(len(nows)):
            now_s = nows[s]
            for i in range(bounds[s], bounds[s + 1]):
                ix = links[i]
                inj = free[ix]
                if now_s > inj:
                    inj = now_s
                injects[i] = inj
                free[ix] = inj + float(serial[i])
        for ix, l in enumerate(self._links):
            l.free = free[ix]
        done = injects + serial + lat
        if status_out is not None:
            nows_row = np.repeat(seg_nows, np.diff(seg_bounds))
            for ri in sorted(set(il)):
                st = self._regions[ri]
                if not self._fault_active(st.region.faults):
                    continue
                rows = np.array([i for i, r in enumerate(il) if r == ri])
                d2, s2 = self._apply_faults(st, nows_row[rows], injects[rows],
                                            serial[rows], done[rows])
                done[rows] = d2
                status_out[rows] = s2
        for s in range(len(nows)):
            lo, hi = bounds[s], bounds[s + 1]
            if lo == hi:
                continue
            seg = il[lo:hi]
            for ri in sorted(set(seg)):
                rows = [lo + i for i, r in enumerate(seg) if r == ri]
                st = self._regions[ri]
                st.ledger.record_batch(nows[s], done[rows])
                nb = int(sizes[rows].sum())
                st.requests += len(rows)
                st.bytes_moved += nb
                self.requests += len(rows)
                self.bytes_moved += nb
        return done

    def _fused_routed(self, seg_nows, seg_bounds, sizes,
                      idx, status_out=None) -> np.ndarray:
        """Reordered mixed-tier issue over unlimited regions.

        The scalar loop's per-row work factors exactly: latency draws only
        touch the row's region RNG (per-region fills in row order consume
        each bitstream identically), injection only touches the row's link
        (per-link chains in row order reproduce the interleaved link_free
        evolution), and nothing couples to completions (no backpressure).
        Ledger/stat updates chunk per (segment, region) to mirror the
        per-command batch path's float association.
        """
        n = sizes.size
        if n <= 16 and self._jit_chain is None:
            return self._fused_routed_small(seg_nows, seg_bounds, sizes, idx,
                                            status_out)
        serial = sizes / self._bw_table[idx]
        lat = np.empty(n, np.float64)
        for ri in np.unique(idx):
            rows = np.flatnonzero(idx == ri)
            lat[rows] = self._region_lat(self._regions[int(ri)], rows.size)
            r = self._regions[int(ri)].region
            self._charge_link(r.link or r.name, float(serial[rows].sum()))
        free = np.array([l.free for l in self._links], np.float64)
        injects = self._chain_inject(seg_nows, seg_bounds, serial,
                                     self._link_table[idx], free)
        for ix, link in enumerate(self._links):
            link.free = float(free[ix])
        done = injects + serial + lat
        if status_out is not None:
            nows_row = np.repeat(seg_nows, np.diff(seg_bounds))
            for ri in np.unique(idx):
                st = self._regions[int(ri)]
                if not self._fault_active(st.region.faults):
                    continue
                rows = np.flatnonzero(idx == ri)
                d2, s2 = self._apply_faults(st, nows_row[rows], injects[rows],
                                            serial[rows], done[rows])
                done[rows] = d2
                status_out[rows] = s2
        for s in range(seg_nows.size):
            lo, hi = int(seg_bounds[s]), int(seg_bounds[s + 1])
            if lo == hi:
                continue
            now_s = float(seg_nows[s])
            seg_idx = idx[lo:hi]
            for ri in np.unique(seg_idx):
                rows = lo + np.flatnonzero(seg_idx == ri)
                st = self._regions[int(ri)]
                st.ledger.record_batch(now_s, done[rows])
                nb = int(sizes[rows].sum())
                st.requests += rows.size
                st.bytes_moved += nb
                self.requests += rows.size
                self.bytes_moved += nb
        return done

    def _fused_flat(self, seg_nows, seg_bounds, sizes,
                    status_out=None) -> np.ndarray:
        """Epoch-fused issue against the flat (regionless) unlimited model."""
        cfg = self.config
        n = sizes.size
        serial = sizes / cfg.bandwidth_bytes_per_cycle
        free = np.array([self._link_free], np.float64)
        injects = self._chain_inject(seg_nows, seg_bounds, serial,
                                     np.zeros(n, np.int64), free)
        self._link_free = float(free[0])
        self._charge_link("far", float(serial.sum()))
        if cfg.distribution is not None:
            lat = cfg.base_latency_cycles * cfg.distribution.draw(self._rng, n)
            done = injects + serial + lat
        elif cfg.jitter_frac:
            lat = cfg.base_latency_cycles * (
                1.0 + cfg.jitter_frac * self._rng.uniform(-1.0, 1.0, size=n))
            done = injects + serial + lat
        else:
            done = injects + serial + cfg.base_latency_cycles
        if status_out is not None:
            nows_row = np.repeat(seg_nows, np.diff(seg_bounds))
            done, status_out[:] = self._apply_faults(None, nows_row, injects,
                                                     serial, done)
        for s in range(seg_nows.size):
            lo, hi = int(seg_bounds[s]), int(seg_bounds[s + 1])
            if lo != hi:
                self._ledger.record_batch(float(seg_nows[s]), done[lo:hi])
        self.requests += n
        self.bytes_moved += int(sizes.sum())
        return done

    def issue_epoch(self, seg_nows, seg_bounds, sizes,
                    addrs=None) -> np.ndarray:
        """One far-memory entry for a whole scheduler epoch of batches.

        ``seg_bounds`` (length S+1) partitions the rows into S segments;
        segment s was issued at ``seg_nows[s]``. Bit-identical to calling
        ``issue_batch(seg_nows[s], sizes[lo:hi], addrs[lo:hi])`` once per
        segment: fully fused when nothing the epoch touches is
        backpressured, otherwise an exact per-segment replay (injection
        under ``max_inflight`` is coupled to completions through a heap,
        which no reordering can untangle).
        """
        sizes = np.asarray(sizes, np.float64)
        seg_nows = np.asarray(seg_nows, np.float64)
        seg_bounds = np.asarray(seg_bounds, np.int64)
        n = sizes.size
        if n == 0:
            return np.empty(0, np.float64)
        status = np.zeros(n, np.int8) if self.fault_enabled else None
        if status is not None:
            self.last_statuses = status
        if self._regions is not None:
            addrs = np.asarray(addrs, np.int64) if addrs is not None else None
            idx = self._route_batch(sizes, addrs)
            if not self._mi_table[np.unique(idx)].any():
                return self._fused_routed(seg_nows, seg_bounds, sizes, idx,
                                          status)
            out = np.empty(n, np.float64)
            for s in range(seg_nows.size):
                lo, hi = int(seg_bounds[s]), int(seg_bounds[s + 1])
                if lo != hi:
                    out[lo:hi] = self._region_issue_batch_routed(
                        float(seg_nows[s]), sizes[lo:hi], addrs[lo:hi],
                        status[lo:hi] if status is not None else None)
            return out
        if self.config.max_inflight:
            out = np.empty(n, np.float64)
            for s in range(seg_nows.size):
                lo, hi = int(seg_bounds[s]), int(seg_bounds[s + 1])
                if lo != hi:
                    out[lo:hi] = self._issue_batch_backpressured(
                        float(seg_nows[s]), sizes[lo:hi],
                        status[lo:hi] if status is not None else None)
            return out
        return self._fused_flat(seg_nows, seg_bounds, sizes, status)

    def _region_batch(self, st: _RegionState, now: float,
                      sizes: np.ndarray, status_out=None) -> np.ndarray:
        """Unlimited-mode vector issue against one region (flat-path math)."""
        r = st.region
        n = sizes.size
        serial = sizes / r.bandwidth_bytes_per_cycle
        injects = np.empty(n, np.float64)
        injects[0] = max(now, st.link.free)
        injects[1:] = serial[:-1]
        np.cumsum(injects, out=injects)
        done = injects + serial + self._region_lat(st, n)
        st.link.free = float(injects[-1]) + float(serial[-1])
        self._charge_link(r.link or r.name, float(serial.sum()))
        if status_out is not None and self._fault_active(r.faults):
            done, status_out[:] = self._apply_faults(st, now, injects, serial,
                                                     done)
        st.ledger.record_batch(now, done)
        st.requests += n
        st.bytes_moved += int(sizes.sum())
        self.requests += n
        self.bytes_moved += int(sizes.sum())
        return done

    def _region_batch_backpressured(self, st: _RegionState, now: float,
                                    sizes: np.ndarray,
                                    status_out=None) -> np.ndarray:
        """Backpressured vector issue against one region: the flat chunked
        admission replayed against the region's heap/link/RNG."""
        r = st.region
        hp = st.inflight
        n = sizes.size
        if status_out is not None and not self._fault_active(r.faults):
            status_out = None           # nothing to classify for this region
        serial = sizes / r.bandwidth_bytes_per_cycle
        dones = np.empty(n, np.float64)
        starts = np.empty(n, np.float64)
        i = 0
        while i < n:
            while hp and hp[0][0] <= now:
                heapq.heappop(hp)
            room = r.max_inflight - len(hp)
            if room > 0:
                k = min(room, n - i)
                chunk = serial[i:i + k]
                inject0 = max(now, st.link.free)
                injects = np.cumsum(np.concatenate([[inject0], chunk[:-1]]))
                dk = injects + chunk + self._region_lat(st, k)
                st.link.free = float(injects[-1]) + float(chunk[-1])
                if status_out is not None:
                    dk, status_out[i:i + k] = self._apply_faults(
                        st, now, injects, chunk, dk)
                for d in dk:
                    st.token += 1
                    heapq.heappush(hp, (float(d), st.token))
                dones[i:i + k] = dk
                starts[i:i + k] = now
                i += k
            else:
                inject_at = max(now, st.link.free, hp[0][0])
                while hp and hp[0][0] <= inject_at:
                    heapq.heappop(hp)
                d = inject_at + float(serial[i]) + self._region_lat(st)
                st.link.free = inject_at + float(serial[i])
                if status_out is not None:
                    d1, s1 = self._apply_faults(
                        st, inject_at, np.array([inject_at]),
                        np.array([float(serial[i])]), np.array([d]))
                    d = float(d1[0])
                    status_out[i] = s1[0]
                st.token += 1
                heapq.heappush(hp, (d, st.token))
                dones[i] = d
                starts[i] = inject_at
                i += 1
        self._charge_link(r.link or r.name, float(serial.sum()))
        st.ledger.record_batch(starts, dones)
        st.requests += n
        st.bytes_moved += int(sizes.sum())
        self.requests += n
        self.bytes_moved += int(sizes.sum())
        return dones

    def reset_stats(self) -> None:
        """Zero the request/byte/MLP counters AND the queueing state: link
        serialization points, backpressure heaps, and token counters all
        clear, so a measured phase after a warmup starts from an idle device
        instead of inheriting the warmup's link occupancy (requests in
        flight at the reset stop contributing to MLP — the ledger is
        cleared). The RNG streams deliberately continue (resetting them
        would replay the warmup's latency draws) — the fault streams too,
        for the same reason — but all fault counters and the out-of-band
        status channel clear, so prepare-phase faults can't leak into a
        measured execute() split."""
        self.requests = 0
        self.bytes_moved = 0
        self.link_busy.clear()
        self._ledger.clear()
        self._link_free = 0.0
        self._inflight.clear()
        self._token = 0
        self.errors = 0
        self.timeouts = 0
        self.stalls = 0
        self.last_status = STATUS_OK
        self.last_statuses = None
        if self._regions is not None:
            for st in self._regions:
                st.requests = 0
                st.bytes_moved = 0
                st.ledger.clear()
                st.inflight.clear()
                st.token = 0
                st.link.free = 0.0
                st.errors = 0
                st.timeouts = 0
                st.stalls = 0


class InstantMemory(FarMemoryModel):
    """Zero-latency functional mode (used when the engine is an oracle)."""

    def __init__(self) -> None:
        super().__init__(FarMemoryConfig(base_latency_cycles=0.0,
                                         bandwidth_bytes_per_cycle=float("inf")))

    def issue(self, now: float, size_bytes: int,
              addr: Optional[int] = None) -> float:
        self.requests += 1
        self.bytes_moved += size_bytes
        return now

    def issue_batch(self, now: float, sizes: "np.ndarray",
                    addrs: Optional["np.ndarray"] = None) -> "np.ndarray":
        sizes = np.asarray(sizes)
        self.requests += sizes.size
        self.bytes_moved += int(sizes.sum()) if sizes.size else 0
        return np.full(sizes.size, now, np.float64)

    def issue_epoch(self, seg_nows, seg_bounds, sizes,
                    addrs=None) -> "np.ndarray":
        sizes = np.asarray(sizes)
        self.requests += sizes.size
        self.bytes_moved += int(sizes.sum()) if sizes.size else 0
        return np.repeat(np.asarray(seg_nows, np.float64),
                         np.diff(np.asarray(seg_bounds, np.int64)))
