"""The paper's contribution: AMI semantics, AMU engine, coroutine framework,
software memory disambiguation, and the calibrated performance model."""
from repro.core.coroutines import (Acquire, Aload, AloadNoWait, Astore,
                                   AstoreNoWait, AwaitRid, BatchScheduler,
                                   Cost, CostModel, Release, Scheduler,
                                   SpmRead, SpmWrite)
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import (AsyncMemoryEngine, BatchedAsyncMemoryEngine,
                               make_engine)
from repro.core.farmem import FarMemoryConfig, FarMemoryModel, InstantMemory
