"""The paper's contribution: AMI semantics, AMU engine, coroutine framework,
software memory disambiguation, and the calibrated performance model.

The public programming surface (config + session + registry + command
facade) lives in :mod:`repro.amu`; this package holds the mechanism."""
from repro.core.coroutines import (Acquire, AcquireVec, Aload, AloadNoWait,
                                   AloadVec, Astore, AstoreNoWait, AstoreVec,
                                   AwaitRid, AwaitRids, BatchScheduler, Cost,
                                   CostModel, Release, ReleaseVec, Scheduler,
                                   SpmRead, SpmWrite)
from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import (AsyncMemoryEngine, BatchedAsyncMemoryEngine,
                               make_engine)
from repro.core.farmem import (BimodalTail, FarMemoryConfig, FarMemoryModel,
                               FarMemoryRegion, InstantMemory,
                               LatencyDistribution, LognormalLatency,
                               UniformJitter)
