"""AsyncMemoryEngine — architectural semantics of AMI (Table 1) + AMU state.

This is the host-side reference model of the paper's contribution:

* ``aload(spm_addr, mem_addr)``  -> request ID (0 == allocation failure)
* ``astore(spm_addr, mem_addr)`` -> request ID (0 == allocation failure)
* ``getfin()``                   -> completed request ID (0 == none finished)
* config registers: ``granularity``, ``queue_base``, ``queue_length``

State mirrors the ASMC's three SPM-resident structures (§4.1): a **free list**,
a **finished list**, and the **AMART** (request table indexed by ID). Data
moves only between the SPM (a byte array standing in for the repurposed L2
slice / TPU VMEM slot ring) and far memory; register<->SPM traffic uses
:meth:`spm_read`/:meth:`spm_write` (the synchronous load/store half of the
paper's split).

The engine is *timed*: every request is scheduled on a
:class:`~repro.core.farmem.FarMemoryModel` and completes when the driver
advances the clock past its completion time. With :class:`InstantMemory` it
degenerates to a functional oracle used by the kernel tests.

ID batching (§4.2 metadata batching) is modeled: the ALSU-side list-vector
register caches up to ``batch_ids`` free/finished IDs, so steady-state
aload/getfin touch the (slower) ASMC lists only every ``batch_ids`` calls.
``batch_ids=1`` reproduces the paper's **AMU (DMA-mode)** ablation.

Two implementations share the AMI contract:

* :class:`AsyncMemoryEngine` — the scalar reference ("oracle"): per-event
  heapq, dataclass AMART entries. Kept deliberately simple; every batched
  behaviour is differentially tested against it.
* :class:`BatchedAsyncMemoryEngine` — structure-of-arrays AMART, ring-buffer
  free/finished lists, and vectorized completion retirement. Call-for-call
  **trace-identical** to the scalar engine (same IDs, same done-times, same
  SPM/far-memory bytes, same stats), but adds batch entry points
  (:meth:`aload_batch`, :meth:`astore_batch`, :meth:`getfin_all`) that move
  whole vectors of requests per Python-level call — the §4.2 metadata-batching
  idea applied to the host model itself.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import EngineConfig
from repro.core.farmem import FarMemoryModel, InstantMemory

AMART_ENTRY_BYTES = 16   # §3.2: SPM addr, mem addr, status, impl. bits
LOAD, STORE = 0, 1


def format_race(where: str, what: str, lo: int, hi: int, rid: int,
                w_lo: int, w_hi: int, port: str = "") -> str:
    """Shared diagnostic for SPM-vs-in-flight-DMA races: used by the scalar
    oracle's assertion and by the runtime sanitizer, so the message shape
    is identical no matter which engine caught the race."""
    who = f"rid={rid}" + (f" (port {port!r})" if port else "")
    return (f"{where}{what} [{lo}, {hi}) races in-flight aload "
            f"{who} -> [{w_lo}, {w_hi}); await it first")


@dataclass
class Request:
    rid: int
    kind: int                 # LOAD | STORE
    spm_addr: int
    mem_addr: int
    size: int
    issue_time: float
    done_time: float = 0.0
    data: Optional[bytes] = None  # astore payload captured at issue
    status: int = 0               # AMART status (§3.2): farmem.STATUS_*


class SpmOverflow(ValueError):
    pass


class AsyncEngineBase:
    """Shared SPM/config plumbing for the scalar and batched engines."""

    #: True when the engine accepts epoch staging (stage_epoch/flush_epoch/
    #: getfin_epoch); the EpochScheduler probes this and falls back to the
    #: per-command batched protocol when it's absent.
    supports_epoch = False

    def __init__(self, config: EngineConfig,
                 far_memory: Optional[FarMemoryModel] = None,
                 backing: Optional[np.ndarray] = None,
                 record_trace: bool = False, label: str = ""):
        self.config = config
        # diagnostic tag ("core3" in a rack) prefixing invariant failures,
        # so a multi-engine run names the stack that leaked an ID
        self.label = label
        self.far = far_memory or InstantMemory()
        # far-memory backing store (uint8); tests pass real arrays here
        self.mem = backing if backing is not None else np.zeros(1 << 20, np.uint8)
        meta_bytes = config.queue_length * AMART_ENTRY_BYTES
        if meta_bytes >= config.spm_bytes:
            raise SpmOverflow(
                f"queue_length={config.queue_length} needs {meta_bytes}B of "
                f"metadata but SPM is {config.spm_bytes}B")
        # data area = SPM minus the AMART/queue metadata area (queue_base..)
        self.spm_data_bytes = config.spm_bytes - meta_bytes
        self.spm = np.zeros(self.spm_data_bytes, np.uint8)
        self.now = 0.0
        # differential-test hook: ("issue", kind, rid, spm, mem, size, done)
        # and ("fin", rid) tuples, in call order
        self.trace: Optional[list] = [] if record_trace else None
        self.stats = {"aload": 0, "astore": 0, "getfin": 0, "getfin_empty": 0,
                      "alloc_fail": 0, "free_refills": 0, "fin_refills": 0}
        # host-side observability (NOT architectural state): Python-level
        # crossings of the AMI surface and the rows they carried. One scalar
        # aload = 1 entry / 1 row; one flush_epoch = 1 entry / n rows.
        self.host_entries = 0
        self.host_rows = 0
        # fault mode: statuses ride the AMART out-of-band with the done
        # times; after getfin() `fin_status` holds the retired request's
        # status, after getfin_all()/getfin_epoch() `fin_statuses` aligns
        # with the returned rids. Only maintained when the far model
        # injects faults — zero-fault runs never touch these.
        self.fault_enabled = bool(getattr(self.far, "fault_enabled", False))
        self.fin_status = 0
        self.fin_statuses = None
        # AmuConfig(sanitize=True) shadow-state checker (see
        # repro.analysis.sanitizer); None = every hook below is skipped.
        # `port_name` is a pure diagnostic tag (sessions stamp the running
        # port's name) used only in race/leak messages.
        self.sanitizer = None
        self.port_name = ""

    # ----------------------------------------------------------------- AMI
    def aload(self, spm_addr: int, mem_addr: int, size: Optional[int] = None) -> int:
        """Far memory -> SPM. Returns request ID, 0 if ID allocation failed."""
        self.host_entries += 1
        self.host_rows += 1
        return self._issue(LOAD, spm_addr, mem_addr, size)

    def astore(self, spm_addr: int, mem_addr: int, size: Optional[int] = None) -> int:
        """SPM -> far memory. Returns request ID, 0 if ID allocation failed."""
        self.host_entries += 1
        self.host_rows += 1
        return self._issue(STORE, spm_addr, mem_addr, size)

    def getfin_all(self) -> List[int]:
        """Drain every currently-completed ID (in finished-list order)."""
        out: List[int] = []
        if self.fault_enabled:
            sts: List[int] = []
            while True:
                rid = self.getfin()
                if rid == 0:
                    self.fin_statuses = sts
                    return out
                out.append(rid)
                sts.append(self.fin_status)
        while True:
            rid = self.getfin()
            if rid == 0:
                return out
            out.append(rid)

    # Batch AMI entry points. The base implementations loop the scalar issue
    # path, so vector commands (AloadVec/AstoreVec) run against any engine;
    # BatchedAsyncMemoryEngine overrides them with true vector paths.
    def aload_batch(self, spm_addrs, mem_addrs, sizes=None) -> np.ndarray:
        """Vectorized aload: returns rids (0 where ID allocation failed)."""
        self.host_entries += 1
        self.host_rows += int(np.size(spm_addrs))
        return self._issue_seq(LOAD, spm_addrs, mem_addrs, sizes)

    def astore_batch(self, spm_addrs, mem_addrs, sizes=None) -> np.ndarray:
        """Vectorized astore: returns rids (0 where ID allocation failed)."""
        self.host_entries += 1
        self.host_rows += int(np.size(spm_addrs))
        return self._issue_seq(STORE, spm_addrs, mem_addrs, sizes)

    def _issue_seq(self, kind: int, spm_addrs, mem_addrs,
                   sizes=None) -> np.ndarray:
        spm_addrs = np.asarray(spm_addrs, np.int64)
        mem_addrs = np.asarray(mem_addrs, np.int64)
        n = spm_addrs.size
        if sizes is None:
            szs = [None] * n
        elif np.ndim(sizes) == 0:              # shared granularity
            szs = [int(sizes)] * n
        else:
            szs = [int(s) for s in np.asarray(sizes, np.int64).ravel()]
        rids = np.zeros(n, np.int64)
        for i in range(n):
            rids[i] = self._issue(kind, int(spm_addrs[i]), int(mem_addrs[i]),
                                  szs[i])
        return rids

    # -------------------------------------------- config registers (Table 1)
    CFG_REGISTERS = ("granularity", "queue_base", "queue_length")

    def cfgrr(self, reg: str) -> int:
        """Read a configuration register into a 'GPR' (Table 1)."""
        if reg == "granularity":
            return self.config.granularity
        if reg == "queue_base":
            return self.spm_data_bytes        # metadata area starts past data
        if reg == "queue_length":
            return self.config.queue_length
        raise KeyError(reg)

    def cfgrw(self, reg: str, value: int) -> None:
        """Write a configuration register. `queue_length` re-initializes the
        metadata area (only legal with no requests outstanding — the paper's
        software contract for reconfiguration)."""
        if reg == "granularity":
            self.config = dataclasses.replace(self.config, granularity=value)
            return
        if reg == "queue_length":
            if self.outstanding or self.finished_pending or self.active_requests:
                raise RuntimeError("cannot resize queue with requests in flight")
            meta = value * AMART_ENTRY_BYTES
            if meta >= self.config.spm_bytes:
                raise SpmOverflow("queue_length metadata exceeds SPM")
            self.config = dataclasses.replace(self.config, queue_length=value)
            self.spm_data_bytes = self.config.spm_bytes - meta
            self.spm = self.spm[:self.spm_data_bytes].copy() if \
                self.spm.size > self.spm_data_bytes else np.concatenate(
                    [self.spm, np.zeros(self.spm_data_bytes - self.spm.size,
                                        np.uint8)])
            self._reset_id_pool(value)
            return
        raise KeyError(reg)

    # ------------------------------------------------- synchronous SPM access
    #
    # Zero-copy contract: `spm_read` returns a READ-ONLY numpy view aliasing
    # the live SPM byte array — NOT a snapshot. The view observes every later
    # `spm_write` and every DMA retirement that lands in its range; a port
    # that needs the bytes to survive such an overwrite must `.copy()` (or
    # double-buffer its SPM slots). Views are never writable: all mutation
    # goes through `spm_write`, which accepts bytes or any C-contiguous
    # ndarray (so ports can hand back computed arrays without `.tobytes()`).
    def spm_write(self, spm_addr: int, data) -> None:
        if isinstance(data, np.ndarray):
            arr = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        else:
            arr = np.frombuffer(data, np.uint8)
        self._check_bounds(spm_addr, arr.size, "spm_write")
        if self.sanitizer is not None:
            self.sanitizer.on_spm_access(spm_addr, arr.size, "spm_write")
        self.spm[spm_addr:spm_addr + arr.size] = arr

    def spm_read(self, spm_addr: int, size: int) -> np.ndarray:
        self._check_bounds(spm_addr, size, "spm_read")
        if self.sanitizer is not None:
            self.sanitizer.on_spm_access(spm_addr, size, "spm_read")
        view = self.spm[spm_addr:spm_addr + size]
        view.flags.writeable = False
        return view

    def _check_bounds(self, spm_addr: int, size: int,
                      what: str = "SPM access") -> None:
        if spm_addr < 0 or size < 0 or spm_addr + size > self.spm_data_bytes:
            raise SpmOverflow(f"{what} [{spm_addr}, {spm_addr+size}) "
                              f"outside data area of {self.spm_data_bytes}B")

    def drain(self) -> None:
        """Advance past every outstanding completion (functional mode helper)."""
        while self.outstanding:
            self.advance(self.next_completion_time)

    @property
    def free_ids(self) -> int:
        """IDs currently allocatable (ASMC free list + ALSU cache)."""
        return len(self._free) + len(self._free_cache)

    @property
    def _where(self) -> str:
        return f"{self.label}: " if self.label else ""

    # subclass responsibilities --------------------------------------------
    def advance(self, now: float) -> None:
        raise NotImplementedError

    def getfin(self) -> int:
        raise NotImplementedError

    def _issue(self, kind: int, spm_addr: int, mem_addr: int,
               size: Optional[int]) -> int:
        raise NotImplementedError

    def _reset_id_pool(self, queue_length: int) -> None:
        raise NotImplementedError

    def done_time(self, rid: int) -> float:
        raise NotImplementedError

    def done_times(self, rids) -> np.ndarray:
        """Vector :meth:`done_time` (schedulers use it for wake planning)."""
        return np.array([self.done_time(int(r)) for r in np.ravel(rids)])

    @property
    def active_requests(self) -> int:
        """Number of allocated IDs (AMART entries in use)."""
        raise NotImplementedError


class AsyncMemoryEngine(AsyncEngineBase):
    """Scalar reference engine — the differential-testing oracle.

    As the oracle it also polices the zero-copy contract: a synchronous SPM
    access that overlaps the destination of an in-flight LOAD is a data race
    (the DMA will clobber, or race with, the access) and raises immediately
    here, so view-aliasing bugs fail loudly in differential tests instead of
    silently corrupting the batched path. In-flight STOREs don't conflict:
    their payload was captured at issue.
    """

    def __init__(self, config: EngineConfig,
                 far_memory: Optional[FarMemoryModel] = None,
                 backing: Optional[np.ndarray] = None,
                 record_trace: bool = False, label: str = ""):
        super().__init__(config, far_memory, backing, record_trace, label)
        # ASMC-side lists (IDs are 1-based; 0 is the failure code)
        self._free: Deque[int] = deque(range(1, config.queue_length + 1))
        self._finished: Deque[int] = deque()
        self.amart: Dict[int, Request] = {}
        self._pending: List[Tuple[float, int]] = []  # (done_time, rid)
        # ALSU list-vector registers (metadata batching caches)
        self._free_cache: Deque[int] = deque()
        self._fin_cache: Deque[int] = deque()

    # ------------------------------------------------------------------ time
    def advance(self, now: float) -> None:
        """Move the clock; retire far-memory completions into the finished list."""
        self.now = max(self.now, now)
        while self._pending and self._pending[0][0] <= self.now:
            _, rid = heapq.heappop(self._pending)
            if self.sanitizer is not None:
                self.sanitizer.on_retire((rid,))
            req = self.amart[rid]
            if req.status != 0:
                # failed request: no data moved (a LOAD leaves the SPM slot
                # stale, a STORE leaves far memory unwritten) — recovery is
                # the scheduler's RetryPolicy, not silent completion
                self._finished.append(rid)
                continue
            if req.kind == LOAD:
                src = self.mem[req.mem_addr:req.mem_addr + req.size]
                self.spm[req.spm_addr:req.spm_addr + req.size] = src
            else:
                self.mem[req.mem_addr:req.mem_addr + req.size] = np.frombuffer(
                    req.data, np.uint8)
            self._finished.append(rid)

    @property
    def outstanding(self) -> int:
        return len(self._pending)

    @property
    def next_completion_time(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    # ------------------------------------------- zero-copy race detection
    def _assert_no_inflight_load_overlap(self, spm_addr: int, size: int,
                                         what: str) -> None:
        end = spm_addr + size
        for _, rid in self._pending:
            req = self.amart[rid]
            if (req.kind == LOAD and spm_addr < req.spm_addr + req.size
                    and req.spm_addr < end):
                raise AssertionError(format_race(
                    self._where, what, spm_addr, end, rid,
                    req.spm_addr, req.spm_addr + req.size, self.port_name))

    def spm_write(self, spm_addr: int, data) -> None:
        size = data.nbytes if isinstance(data, np.ndarray) else len(data)
        self._assert_no_inflight_load_overlap(spm_addr, size, "spm_write")
        super().spm_write(spm_addr, data)

    def spm_read(self, spm_addr: int, size: int) -> np.ndarray:
        self._assert_no_inflight_load_overlap(spm_addr, size, "spm_read")
        return super().spm_read(spm_addr, size)

    @property
    def finished_pending(self) -> int:
        return len(self._finished) + len(self._fin_cache)

    @property
    def active_requests(self) -> int:
        return len(self.amart)

    def done_time(self, rid: int) -> float:
        return self.amart[rid].done_time

    # ----------------------------------------------------------------- AMI
    def _alloc_id(self) -> int:
        if not self._free_cache:
            if not self._free:
                self.stats["alloc_fail"] += 1
                return 0
            # batch refill from the ASMC free list (one L2-latency round trip)
            n = min(self.config.batch_ids, len(self._free))
            self._free_cache.extend(self._free.popleft() for _ in range(n))
            self.stats["free_refills"] += 1
        return self._free_cache.popleft()

    def _issue(self, kind: int, spm_addr: int, mem_addr: int,
               size: Optional[int]) -> int:
        size = size or self.config.granularity
        self._check_bounds(spm_addr, size)
        rid = self._alloc_id()
        if rid == 0:
            return 0
        req = Request(rid, kind, spm_addr, mem_addr, size, self.now)
        if kind == STORE:
            req.data = self.spm[spm_addr:spm_addr + size].tobytes()
        req.done_time = self.far.issue(self.now, size, mem_addr)
        if self.fault_enabled:
            req.status = self.far.last_status
        self.amart[rid] = req
        heapq.heappush(self._pending, (req.done_time, rid))
        if self.sanitizer is not None:
            self.sanitizer.on_issue(kind, rid, spm_addr, size)
        self.stats["aload" if kind == LOAD else "astore"] += 1
        if self.trace is not None:
            self.trace.append(("issue", kind, rid, spm_addr, mem_addr, size,
                               req.done_time))
        return rid

    def getfin(self) -> int:
        """Return a completed request ID (0 if none). Frees the ID."""
        self.advance(self.now)
        self.host_entries += 1
        self.host_rows += 1
        self.stats["getfin"] += 1
        if not self._fin_cache:
            if not self._finished:
                self.stats["getfin_empty"] += 1
                if self.trace is not None:
                    self.trace.append(("fin", 0))
                return 0
            n = min(self.config.batch_ids, len(self._finished))
            self._fin_cache.extend(self._finished.popleft() for _ in range(n))
            self.stats["fin_refills"] += 1
        rid = self._fin_cache.popleft()
        if self.fault_enabled:
            self.fin_status = self.amart[rid].status
        del self.amart[rid]
        self._free.append(rid)  # ID returns to the ASMC free list
        if self.trace is not None:
            self.trace.append(("fin", rid))
        return rid

    def _reset_id_pool(self, queue_length: int) -> None:
        self._free = deque(range(1, queue_length + 1))
        self._free_cache.clear()
        self._fin_cache.clear()
        self._finished.clear()

    # ----------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """ID conservation: every ID is in exactly one place."""
        ids = (list(self._free) + list(self._free_cache) + list(self._fin_cache)
               + list(self._finished) + [r for _, r in self._pending])
        in_flight_fin = set(self._finished) | set(self._fin_cache)
        pend = {r for _, r in self._pending}
        assert len(ids) == self.config.queue_length, (
            f"{self._where}ID leak: {len(ids)} != {self.config.queue_length}")
        assert len(set(ids)) == len(ids), f"{self._where}duplicate ID"
        assert set(self.amart) == (pend | in_flight_fin), \
            f"{self._where}AMART out of sync"


class _IdRing:
    """Fixed-capacity int64 FIFO ring buffer (the ASMC's SPM-resident lists)."""

    __slots__ = ("buf", "cap", "head", "n")

    def __init__(self, cap: int, fill: Optional[np.ndarray] = None):
        self.cap = cap
        self.buf = np.zeros(cap, np.int64)
        self.head = 0
        self.n = 0
        if fill is not None:
            self.buf[:fill.size] = fill
            self.n = int(fill.size)

    def __len__(self) -> int:
        return self.n

    def pop(self) -> int:
        rid = int(self.buf[self.head])
        self.head = (self.head + 1) % self.cap
        self.n -= 1
        return rid

    def pop_many(self, k: int) -> np.ndarray:
        if self.head + k <= self.cap:                 # contiguous fast path
            out = self.buf[self.head:self.head + k].copy()
        else:
            out = self.buf[(self.head + np.arange(k)) % self.cap].copy()
        self.head = (self.head + k) % self.cap
        self.n -= k
        return out

    def push(self, rid: int) -> None:
        self.buf[(self.head + self.n) % self.cap] = rid
        self.n += 1

    def push_many(self, rids: np.ndarray) -> None:
        k = len(rids)
        p = (self.head + self.n) % self.cap
        if p + k <= self.cap:                          # contiguous fast path
            self.buf[p:p + k] = rids
        else:
            self.buf[(p + np.arange(k)) % self.cap] = rids
        self.n += k

    def tolist(self) -> List[int]:
        return self.buf[(self.head + np.arange(self.n)) % self.cap].tolist()


class BatchedAsyncMemoryEngine(AsyncEngineBase):
    """Structure-of-arrays engine with vectorized completion retirement.

    Scalar AMI calls (`aload`/`astore`/`getfin`) are call-for-call
    trace-identical to :class:`AsyncMemoryEngine`; the batch entry points
    (`aload_batch`/`astore_batch`/`getfin_all`) retire whole vectors per
    Python call, which is what makes latency x queue-depth sweeps tractable.

    On top of those sits the **epoch surface** (`stage_epoch` /
    `flush_epoch` / `getfin_epoch`): the EpochScheduler stages every port's
    issue batch for a whole scheduler epoch and the engine enters the far
    model ONCE with the concatenated SoA mega-batch
    (:meth:`FarMemoryModel.issue_epoch`). Allocation, bounds checks and
    store-payload capture stay at staging time (they observe live SPM/ID
    state); far-model math, AMART scatter, trace rows and the clock advance
    are deferred to the flush — bit-identical to issuing each staged batch
    through `aload_batch`/`astore_batch` at its staged `now`.
    """

    supports_epoch = True

    def __init__(self, config: EngineConfig,
                 far_memory: Optional[FarMemoryModel] = None,
                 backing: Optional[np.ndarray] = None,
                 record_trace: bool = False, label: str = ""):
        super().__init__(config, far_memory, backing, record_trace, label)
        cap = config.queue_length
        self._free = _IdRing(cap, fill=np.arange(1, cap + 1))
        self._finished = _IdRing(cap)
        # ALSU free-ID cache as an array + cursor (bulk allocation pops a
        # slice instead of draining a deque element-wise)
        self._fc = np.empty(0, np.int64)
        self._fc_head = 0
        self._fin_cache: Deque[int] = deque()
        # SoA AMART, indexed by rid (slot 0 unused — 0 is the failure code)
        self._kind = np.zeros(cap + 1, np.int8)
        self._spm_a = np.zeros(cap + 1, np.int64)
        self._mem_a = np.zeros(cap + 1, np.int64)
        self._size = np.zeros(cap + 1, np.int64)
        self._issue_t = np.zeros(cap + 1, np.float64)
        self._done_t = np.zeros(cap + 1, np.float64)
        self._active = np.zeros(cap + 1, bool)
        # per-request AMART status (farmem.STATUS_*); stays all-OK and
        # untouched on the zero-fault path
        self._status = np.zeros(cap + 1, np.int8)
        self._store_data: List[Optional[np.ndarray]] = [None] * (cap + 1)
        # unsorted in-flight rid vector (replaces the per-event heapq)
        self._pend = np.zeros(cap, np.int64)
        self._pend_n = 0
        self._pend_min = math.inf
        # epoch staging: (kind, now, rids, spm, mem, sizes) per staged batch
        self._ep_segs: List[tuple] = []
        self._ep_last_now: Optional[float] = None
        # shared-granularity sizes arrays, reused across batch/stage calls
        # (read-only once handed out; every consumer copies or slices)
        self._gran_cache: Dict[int, np.ndarray] = {}

    # ------------------------------------------------------------------ time
    def advance(self, now: float) -> None:
        """Move the clock; retire ALL due completions in one vectorized step,
        ordered by (done_time, rid) exactly like the scalar heapq."""
        self.now = max(self.now, now)
        if self._pend_n == 0 or self._pend_min > self.now:
            return
        rids = self._pend[:self._pend_n]
        done = self._done_t[rids]
        due = done <= self.now
        fin = rids[due]
        if fin.size > 1:
            fin = fin[np.lexsort((fin, done[due]))]
        if self.fault_enabled and fin.size:
            # failed requests retire without moving data (the scheduler's
            # RetryPolicy owns recovery); retirement order is unchanged
            self._move_data(fin[self._status[fin] == 0])
        else:
            self._move_data(fin)
        if self.sanitizer is not None:
            self.sanitizer.on_retire(fin)
        self._finished.push_many(fin)
        keep = rids[~due]
        self._pend[:keep.size] = keep
        self._pend_n = keep.size
        self._pend_min = float(self._done_t[keep].min()) if keep.size \
            else math.inf

    def _move_data(self, fin: np.ndarray) -> None:
        """Perform the DMA for retired requests, preserving retirement order.

        Consecutive same-kind runs are vectorized; run boundaries keep
        load-after-store ordering on overlapping far-memory regions, and
        in-order fancy assignment keeps last-writer-wins within a run.
        """
        if fin.size == 0:
            return
        kinds = self._kind[fin]
        bounds = [0, *(np.flatnonzero(kinds[1:] != kinds[:-1]) + 1).tolist(),
                  fin.size]
        for b in range(len(bounds) - 1):
            i, j = bounds[b], bounds[b + 1]
            run = fin[i:j]
            if j - i <= 4:                  # few rows: in-order scalar copies
                if kinds[i] == LOAD:        # (the reference semantics) beat
                    for rid in run.tolist():     # the pattern analysis
                        a, m, s = (int(self._spm_a[rid]),
                                   int(self._mem_a[rid]), int(self._size[rid]))
                        self.spm[a:a + s] = self.mem[m:m + s]
                else:
                    for rid in run.tolist():
                        m, s = int(self._mem_a[rid]), int(self._size[rid])
                        self.mem[m:m + s] = self._store_data[rid]
                continue
            sizes = self._size[run]
            same_gran = sizes.size > 1 and bool((sizes == sizes[0]).all())
            if kinds[i] == LOAD:
                if same_gran:
                    self._move_loads_same_gran(run, int(sizes[0]))
                else:
                    # mixed granularities (or a single request): scalar copies
                    for rid in run:
                        a, m, s = (int(self._spm_a[rid]),
                                   int(self._mem_a[rid]), int(self._size[rid]))
                        self.spm[a:a + s] = self.mem[m:m + s]
            else:
                if same_gran:
                    self._move_stores_same_gran(run, int(sizes[0]))
                else:
                    for rid in run:
                        m, s = int(self._mem_a[rid]), int(self._size[rid])
                        self.mem[m:m + s] = self._store_data[rid]

    def _move_loads_same_gran(self, run: np.ndarray, g: int) -> None:
        """Same-granularity load retirement: one copy per run instead of
        O(n*g) fancy-index arithmetic where the access pattern allows.

        Tiers: (1) both sides form one ascending contiguous block -> a single
        reshaped slice copy (sequential workloads: STREAM/IS blocks); (2) g
        is a machine word and everything is g-aligned -> one dtype-view
        gather/scatter of n elements (GUPS-style random words); (3) both
        sides decompose into a FEW piecewise-contiguous segments -> one
        slice copy per segment (vector ports that concatenate several
        sequential slot windows into one AloadVec, e.g. STREAM's b|c
        halves); (4) general same-size 2D fancy gather. In-order
        segment/fancy assignment keeps last-writer-wins for duplicate
        destinations within a run.
        """
        assert g > 0 and (self._size[run] == g).all(), \
            "same-granularity fast path fed mixed sizes"
        spm_a = self._spm_a[run]
        mem_a = self._mem_a[run]
        n = run.size
        d_spm = spm_a[1:] - spm_a[:-1]
        d_mem = mem_a[1:] - mem_a[:-1]
        if (d_spm == g).all() and (d_mem == g).all():
            s0, m0 = int(spm_a[0]), int(mem_a[0])
            self.spm[s0:s0 + n * g] = self.mem[m0:m0 + n * g]
            return
        if g in (1, 2, 4, 8) and not ((spm_a % g).any() or (mem_a % g).any()):
            dt = np.dtype(f"u{g}")
            sv = self.spm[:(self.spm.size // g) * g].view(dt)
            mv = self.mem[:(self.mem.size // g) * g].view(dt)
            sv[spm_a // g] = mv[mem_a // g]
            return
        if g >= 256:          # big blocks: piecewise-contiguous segments
            starts = np.flatnonzero((d_spm != g) | (d_mem != g)) + 1
            if starts.size + 1 <= max(1, n // 4):
                bounds = [0, *starts.tolist(), n]
                for i in range(len(bounds) - 1):
                    lo, hi = bounds[i], bounds[i + 1]
                    s0, m0 = int(spm_a[lo]), int(mem_a[lo])
                    ln = (hi - lo) * g
                    self.spm[s0:s0 + ln] = self.mem[m0:m0 + ln]
                return
        if g % 8 == 0 and not ((spm_a % 8).any() or (mem_a % 8).any()):
            # word-aligned scatter (chase nodes): 8x fewer gathered elements
            w = g // 8
            sv = self.spm[:(self.spm.size // 8) * 8].view(np.uint64)
            mv = self.mem[:(self.mem.size // 8) * 8].view(np.uint64)
            cols = np.arange(w)
            sv[(spm_a // 8)[:, None] + cols] = mv[(mem_a // 8)[:, None] + cols]
            return
        cols = np.arange(g)
        self.spm[spm_a[:, None] + cols] = self.mem[mem_a[:, None] + cols]

    def _move_stores_same_gran(self, run: np.ndarray, g: int) -> None:
        """Same-granularity store retirement (payloads captured at issue)."""
        assert g > 0 and (self._size[run] == g).all(), \
            "same-granularity fast path fed mixed sizes"
        mem_a = self._mem_a[run]
        n = run.size
        # one concatenate over the captured row views — no per-rid fill loop
        store = self._store_data
        data = np.concatenate([store[rid] for rid in run.tolist()]) \
            if n > 1 else store[int(run[0])]
        if (mem_a[1:] - mem_a[:-1] == g).all():
            m0 = int(mem_a[0])
            self.mem[m0:m0 + n * g] = data
            return
        if g in (1, 2, 4, 8) and not (mem_a % g).any():
            dt = np.dtype(f"u{g}")
            mv = self.mem[:(self.mem.size // g) * g].view(dt)
            mv[mem_a // g] = data.view(dt)
            return
        if g % 8 == 0 and not (mem_a % 8).any():
            w = g // 8
            mv = self.mem[:(self.mem.size // 8) * 8].view(np.uint64)
            mv[(mem_a // 8)[:, None] + np.arange(w)] = \
                np.ascontiguousarray(data).view(np.uint64).reshape(n, w)
            return
        self.mem[mem_a[:, None] + np.arange(g)] = data.reshape(n, g)

    @property
    def outstanding(self) -> int:
        return int(self._pend_n)

    @property
    def next_completion_time(self) -> Optional[float]:
        return self._pend_min if self._pend_n else None

    @property
    def finished_pending(self) -> int:
        return len(self._finished) + len(self._fin_cache)

    @property
    def active_requests(self) -> int:
        return int(self._active.sum())

    def done_time(self, rid: int) -> float:
        return float(self._done_t[rid])

    def done_times(self, rids) -> np.ndarray:
        return self._done_t[np.asarray(rids, np.int64)]

    # ----------------------------------------------------------------- AMI
    @property
    def free_ids(self) -> int:
        return len(self._free) + (self._fc.size - self._fc_head)

    def _alloc_id(self) -> int:
        if self._fc_head >= self._fc.size:
            if len(self._free) == 0:
                self.stats["alloc_fail"] += 1
                return 0
            n = min(self.config.batch_ids, len(self._free))
            self._fc = self._free.pop_many(n)
            self._fc_head = 0
            self.stats["free_refills"] += 1
        rid = int(self._fc[self._fc_head])
        self._fc_head += 1
        return rid

    def _alloc_ids(self, n: int) -> np.ndarray:
        """Allocate up to n IDs — state/stat-equivalent to n scalar allocs."""
        head = self._fc_head
        avail = self._fc.size - head
        if n <= avail:                      # cache covers the whole batch
            self._fc_head = head + n
            return self._fc[head:head + n]
        parts = [self._fc[head:]] if avail else []
        self._fc_head = self._fc.size
        need = n - avail
        if need > 0 and len(self._free):
            # replicate the batch_ids-chunked refill accounting (same
            # free_refills count, same leftover cache) with ONE ring pop
            bsz = self.config.batch_ids
            fn = len(self._free)
            refills = total = last = 0
            rem = need
            while rem > 0 and fn:
                last = min(bsz, fn)
                fn -= last
                total += last
                refills += 1
                rem -= min(rem, last)
            got = self._free.pop_many(total)
            self.stats["free_refills"] += refills
            use = min(need, total)
            parts.append(got[:use])
            if use < total:              # leftover becomes the new cache
                self._fc = got[total - last:]
                self._fc_head = use - (total - last)
            need -= use
        if need:
            self.stats["alloc_fail"] += need
        if len(parts) == 1:
            return parts[0]
        return np.concatenate(parts) if parts else self._fc[:0]

    def _set_request(self, rid: int, kind: int, spm_addr: int, mem_addr: int,
                     size: int, done: float) -> None:
        self._kind[rid] = kind
        self._spm_a[rid] = spm_addr
        self._mem_a[rid] = mem_addr
        self._size[rid] = size
        self._issue_t[rid] = self.now
        self._done_t[rid] = done
        self._active[rid] = True
        self._pend[self._pend_n] = rid
        self._pend_n += 1
        if done < self._pend_min:
            self._pend_min = float(done)

    def _issue(self, kind: int, spm_addr: int, mem_addr: int,
               size: Optional[int]) -> int:
        size = size or self.config.granularity
        self._check_bounds(spm_addr, size)
        rid = self._alloc_id()
        if rid == 0:
            return 0
        if kind == STORE:
            self._store_data[rid] = self.spm[spm_addr:spm_addr + size].copy()
        done = self.far.issue(self.now, size, mem_addr)
        if self.fault_enabled:
            self._status[rid] = self.far.last_status
        self._set_request(rid, kind, spm_addr, mem_addr, size, done)
        if self.sanitizer is not None:
            self.sanitizer.on_issue(kind, rid, spm_addr, size)
        self.stats["aload" if kind == LOAD else "astore"] += 1
        if self.trace is not None:
            self.trace.append(("issue", kind, rid, spm_addr, mem_addr, size,
                               done))
        return rid

    def getfin(self) -> int:
        """Return a completed request ID (0 if none). Frees the ID."""
        self.advance(self.now)
        self.host_entries += 1
        self.host_rows += 1
        self.stats["getfin"] += 1
        if not self._fin_cache:
            if len(self._finished) == 0:
                self.stats["getfin_empty"] += 1
                if self.trace is not None:
                    self.trace.append(("fin", 0))
                return 0
            n = min(self.config.batch_ids, len(self._finished))
            self._fin_cache.extend(self._finished.pop_many(n).tolist())
            self.stats["fin_refills"] += 1
        rid = self._fin_cache.popleft()
        if self.fault_enabled:
            self.fin_status = int(self._status[rid])
        self._active[rid] = False
        self._store_data[rid] = None
        self._free.push(rid)
        if self.trace is not None:
            self.trace.append(("fin", rid))
        return rid

    # ------------------------------------------------------- batch AMI path
    def _coerce_batch(self, spm_addrs, mem_addrs, sizes):
        """Shared front half of the batch/epoch issue paths: int64 coercion,
        `size or granularity`, and the vectorized SPM bounds check.
        Returns the shared granularity `g0` too (0 for per-row sizes)."""
        spm_addrs = np.asarray(spm_addrs, np.int64)
        mem_addrs = np.asarray(mem_addrs, np.int64)
        n = spm_addrs.size
        if sizes is None or sizes.__class__ is int or np.ndim(sizes) == 0:
            # shared granularity (`size or granularity`, like the scalar
            # path); the filled array is cached and handed out read-only
            g0 = int(sizes or 0) or self.config.granularity
            sz = self._gran_cache.get(g0)
            if sz is None or sz.size < n:
                sz = np.full(max(n, 1024), g0, np.int64)
                self._gran_cache[g0] = sz
            sizes = sz[:n]
        else:
            # match the scalar path's `size or granularity` coercion
            g0 = 0
            sizes = np.asarray(sizes, np.int64)
            sizes = np.where(sizes == 0, self.config.granularity, sizes)
        if n:
            if g0:
                # shared granularity: two reductions replace the row masks
                ok = (g0 > 0 and int(spm_addrs.min()) >= 0
                      and int(spm_addrs.max()) + g0 <= self.spm_data_bytes)
            else:
                ok = not bool(((spm_addrs < 0) | (sizes < 0)
                               | (spm_addrs + sizes
                                  > self.spm_data_bytes)).any())
            if not ok:
                bad_mask = ((spm_addrs < 0) | (sizes < 0)
                            | (spm_addrs + sizes > self.spm_data_bytes))
                bad = int(np.argmax(bad_mask))
                raise SpmOverflow(
                    f"SPM access [{spm_addrs[bad]}, "
                    f"{spm_addrs[bad] + sizes[bad]}) "
                    f"outside data area of {self.spm_data_bytes}B")
        return spm_addrs, mem_addrs, sizes, n, g0

    def _capture_stores(self, ok: np.ndarray, k: int, spm_addrs: np.ndarray,
                        sizes: np.ndarray, g0: int = 0) -> None:
        """Capture astore payloads from live SPM at issue/staging time.
        `g0` (when nonzero) promises every row shares that granularity."""
        if g0 or (sizes[:k] == sizes[0]).all():
            # same-granularity capture: one copy, row views out — a
            # single reshaped slice when the source slots are contiguous
            # (vector ports), else one fancy gather
            g = g0 or int(sizes[0])
            if k > 1 and (spm_addrs[1:k] - spm_addrs[:k - 1] == g).all():
                a0 = int(spm_addrs[0])
                rows = self.spm[a0:a0 + k * g].copy().reshape(k, g)
            else:
                rows = self.spm[spm_addrs[:k, None] + np.arange(g)]
            store = self._store_data
            for rid, row in zip(ok.tolist(), rows):
                store[rid] = row
        else:
            spm, store = self.spm, self._store_data
            for rid, a, s in zip(ok.tolist(), spm_addrs.tolist(),
                                 sizes.tolist()):
                store[rid] = spm[a:a + s].copy()

    def _issue_batch(self, kind: int, spm_addrs, mem_addrs,
                     sizes=None) -> np.ndarray:
        spm_addrs, mem_addrs, sizes, n, g0 = self._coerce_batch(
            spm_addrs, mem_addrs, sizes)
        self.host_entries += 1
        self.host_rows += n
        got = self._alloc_ids(n)
        k = len(got)
        rids = np.zeros(n, np.int64)
        if k == 0:
            return rids
        ok = np.asarray(got, np.int64)
        rids[:k] = ok
        if kind == STORE:
            self._capture_stores(ok, k, spm_addrs, sizes, g0)
        done = self.far.issue_batch(self.now, sizes[:k], mem_addrs[:k])
        if self.fault_enabled:
            self._status[ok] = self.far.last_statuses
        self._kind[ok] = kind
        self._spm_a[ok] = spm_addrs[:k]
        self._mem_a[ok] = mem_addrs[:k]
        self._size[ok] = sizes[:k]
        self._issue_t[ok] = self.now
        self._done_t[ok] = done
        self._active[ok] = True
        self._pend[self._pend_n:self._pend_n + k] = ok
        self._pend_n += k
        if k:
            self._pend_min = min(self._pend_min, float(done.min()))
        if self.sanitizer is not None:
            self.sanitizer.on_issue_batch(kind, ok, spm_addrs[:k], sizes[:k])
        self.stats["aload" if kind == LOAD else "astore"] += k
        if self.trace is not None:
            for i in range(k):
                self.trace.append(("issue", kind, int(ok[i]),
                                   int(spm_addrs[i]), int(mem_addrs[i]),
                                   int(sizes[i]), float(done[i])))
        return rids

    def aload_batch(self, spm_addrs, mem_addrs, sizes=None) -> np.ndarray:
        """Vectorized aload: returns rids (0 where ID allocation failed)."""
        return self._issue_batch(LOAD, spm_addrs, mem_addrs, sizes)

    def astore_batch(self, spm_addrs, mem_addrs, sizes=None) -> np.ndarray:
        """Vectorized astore: returns rids (0 where ID allocation failed)."""
        return self._issue_batch(STORE, spm_addrs, mem_addrs, sizes)

    def getfin_all(self) -> List[int]:
        """Drain every completed ID in one call — stat/state-equivalent to
        calling ``getfin()`` until it returns 0 (incl. the final empty poll)."""
        self.advance(self.now)
        c, f = len(self._fin_cache), len(self._finished)
        total = c + f
        self.host_entries += 1
        self.host_rows += total
        self.stats["getfin"] += total + 1
        self.stats["getfin_empty"] += 1
        if total == 0:
            if self.fault_enabled:
                self.fin_statuses = []
            if self.trace is not None:
                self.trace.append(("fin", 0))
            return []
        # after the cache drains, the scalar loop refills batch_ids at a time
        self.stats["fin_refills"] += -(-f // self.config.batch_ids) if f else 0
        rids = list(self._fin_cache)
        self._fin_cache.clear()
        if f:
            rids.extend(self._finished.pop_many(f).tolist())
        arr = np.asarray(rids, np.int64)
        if self.fault_enabled:
            self.fin_statuses = self._status[arr].tolist()
        self._active[arr] = False
        for rid in rids:
            self._store_data[rid] = None
        self._free.push_many(arr)
        if self.trace is not None:
            self.trace.extend(("fin", rid) for rid in rids)
            self.trace.append(("fin", 0))
        return rids

    # ------------------------------------------------------ epoch AMI path
    def stage_epoch(self, kind: int, now: float, spm_addrs, mem_addrs,
                    sizes=None) -> np.ndarray:
        """Stage one port's issue batch for the current epoch.

        Everything that observes *live* state happens here, exactly as it
        would on the immediate path: bounds validation, ID allocation from
        the ASMC free list / ALSU cache (the free pool only shrinks between
        the epoch-top drain and the flush, so staged allocs see the same
        pool the per-command path would), astore payload capture from the
        SPM as it is *now*, and the aload/astore stats. The far-model call,
        AMART scatter, trace rows and clock advance are deferred to
        :meth:`flush_epoch`. Returns rids (0 where allocation failed).
        """
        spm_addrs, mem_addrs, sizes, n, g0 = self._coerce_batch(
            spm_addrs, mem_addrs, sizes)
        # remember the epoch's last staged time even if nothing allocates:
        # the flush replays the per-command path's trailing advance()
        self._ep_last_now = float(now)
        got = self._alloc_ids(n)
        k = len(got)
        if k == 0:
            return np.zeros(n, np.int64)
        ok = np.asarray(got, np.int64)
        if k == n:
            rids = ok                       # full allocation: no zero suffix
        else:
            rids = np.zeros(n, np.int64)
            rids[:k] = ok
        if kind == STORE:
            self._capture_stores(ok, k, spm_addrs, sizes, g0)
        if self.sanitizer is not None:
            # staged requests are in flight from staging time: allocation
            # and store capture already happened against live state
            self.sanitizer.on_issue_batch(kind, ok, spm_addrs[:k], sizes[:k])
        self.stats["aload" if kind == LOAD else "astore"] += k
        self._ep_segs.append((kind, float(now), ok, spm_addrs[:k],
                              mem_addrs[:k], sizes[:k]))
        return rids

    @property
    def epoch_staged(self) -> bool:
        """Anything staged (or staged-and-failed) since the last flush —
        when False, ``flush_epoch`` would be a pure no-op."""
        return bool(self._ep_segs) or self._ep_last_now is not None

    def flush_epoch(self) -> np.ndarray:
        """Issue every staged batch with ONE far-model entry.

        Segments keep their staged `now` (``issue_epoch`` replays per-link /
        per-region draw order exactly), the AMART scatter and per-row trace
        run over the concatenated epoch, and the final ``advance`` to the
        last staged time reproduces the cumulative effect of the immediate
        path's per-command advances (retirement batches concatenate to one
        globally (done, rid)-sorted batch because due-sets partition
        monotonically in time). Returns the done-times, epoch row order.
        """
        segs = self._ep_segs
        last = self._ep_last_now
        self._ep_segs = []
        self._ep_last_now = None
        if not segs:
            if last is not None:
                self.advance(last)
            return np.empty(0, np.float64)
        if len(segs) == 1:
            # one staged batch: issue_epoch over a single segment is defined
            # as exactly one issue_batch — take it directly, skipping the
            # concat/repeat machinery
            kind0, now0, ok, spm, mem, sizes = segs[0]
            k = int(ok.size)
            self.host_entries += 1
            self.host_rows += k
            done = self.far.issue_batch(now0, sizes, mem)
            if self.fault_enabled:
                self._status[ok] = self.far.last_statuses
            self._kind[ok] = kind0
            self._spm_a[ok] = spm
            self._mem_a[ok] = mem
            self._size[ok] = sizes
            self._issue_t[ok] = now0
            self._done_t[ok] = done
            self._active[ok] = True
            self._pend[self._pend_n:self._pend_n + k] = ok
            self._pend_n += k
            self._pend_min = min(self._pend_min, float(done.min()))
            if self.trace is not None:
                for i in range(k):
                    self.trace.append(("issue", kind0, int(ok[i]),
                                       int(spm[i]), int(mem[i]),
                                       int(sizes[i]), float(done[i])))
            self.advance(last)
            return done
        ks = np.array([s[2].size for s in segs], np.int64)
        seg_nows = np.array([s[1] for s in segs], np.float64)
        seg_bounds = np.zeros(ks.size + 1, np.int64)
        np.cumsum(ks, out=seg_bounds[1:])
        ok = np.concatenate([s[2] for s in segs])
        spm = np.concatenate([s[3] for s in segs])
        mem = np.concatenate([s[4] for s in segs])
        sizes = np.concatenate([s[5] for s in segs])
        k = int(ok.size)
        self.host_entries += 1
        self.host_rows += k
        done = self.far.issue_epoch(seg_nows, seg_bounds, sizes, mem)
        if self.fault_enabled:
            self._status[ok] = self.far.last_statuses
        kinds = np.repeat(np.array([s[0] for s in segs], np.int8), ks)
        self._kind[ok] = kinds
        self._spm_a[ok] = spm
        self._mem_a[ok] = mem
        self._size[ok] = sizes
        self._issue_t[ok] = np.repeat(seg_nows, ks)
        self._done_t[ok] = done
        self._active[ok] = True
        self._pend[self._pend_n:self._pend_n + k] = ok
        self._pend_n += k
        self._pend_min = min(self._pend_min, float(done.min()))
        if self.trace is not None:
            for i in range(k):
                self.trace.append(("issue", int(kinds[i]), int(ok[i]),
                                   int(spm[i]), int(mem[i]), int(sizes[i]),
                                   float(done[i])))
        self.advance(last)
        return done

    def getfin_epoch(self, now: float) -> Optional[List[int]]:
        """Epoch-top drain: advance to `now`, then ``getfin_all`` iff
        anything finished. Returns None when nothing was pending — the
        same gate the per-command scheduler applies before draining, so the
        trace/stats stay call-for-call identical."""
        self.advance(now)
        if not self.finished_pending:
            return None
        return self.getfin_all()

    def _reset_id_pool(self, queue_length: int) -> None:
        cap = queue_length
        self._free = _IdRing(cap, fill=np.arange(1, cap + 1))
        self._finished = _IdRing(cap)
        self._fc = np.empty(0, np.int64)
        self._fc_head = 0
        self._fin_cache.clear()
        self._kind = np.zeros(cap + 1, np.int8)
        self._spm_a = np.zeros(cap + 1, np.int64)
        self._mem_a = np.zeros(cap + 1, np.int64)
        self._size = np.zeros(cap + 1, np.int64)
        self._issue_t = np.zeros(cap + 1, np.float64)
        self._done_t = np.zeros(cap + 1, np.float64)
        self._active = np.zeros(cap + 1, bool)
        self._status = np.zeros(cap + 1, np.int8)
        self._store_data = [None] * (cap + 1)
        self._pend = np.zeros(cap, np.int64)
        self._pend_n = 0
        self._pend_min = math.inf

    # ----------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """ID conservation: every ID is in exactly one place."""
        pend = self._pend[:self._pend_n].tolist()
        ids = (self._free.tolist() + self._fc[self._fc_head:].tolist()
               + list(self._fin_cache) + self._finished.tolist() + pend)
        assert len(ids) == self.config.queue_length, (
            f"{self._where}ID leak: {len(ids)} != {self.config.queue_length}")
        assert len(set(ids)) == len(ids), f"{self._where}duplicate ID"
        in_flight = (set(pend) | set(self._finished.tolist())
                     | set(self._fin_cache))
        assert set(np.nonzero(self._active)[0].tolist()) == in_flight, \
            f"{self._where}AMART out of sync"


ENGINE_KINDS = {"scalar": AsyncMemoryEngine, "batched": BatchedAsyncMemoryEngine}


def make_engine(kind: str, config: EngineConfig,
                far_memory: Optional[FarMemoryModel] = None,
                backing: Optional[np.ndarray] = None,
                record_trace: bool = False, label: str = "") -> AsyncEngineBase:
    """Factory for the `engine=` knob: "scalar" (oracle) or "batched"."""
    try:
        cls = ENGINE_KINDS[kind]
    except KeyError:
        raise KeyError(f"unknown engine kind {kind!r}; "
                       f"known: {sorted(ENGINE_KINDS)}") from None
    return cls(config, far_memory, backing, record_trace=record_trace,
               label=label)
