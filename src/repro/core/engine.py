"""AsyncMemoryEngine — architectural semantics of AMI (Table 1) + AMU state.

This is the host-side reference model of the paper's contribution:

* ``aload(spm_addr, mem_addr)``  -> request ID (0 == allocation failure)
* ``astore(spm_addr, mem_addr)`` -> request ID (0 == allocation failure)
* ``getfin()``                   -> completed request ID (0 == none finished)
* config registers: ``granularity``, ``queue_base``, ``queue_length``

State mirrors the ASMC's three SPM-resident structures (§4.1): a **free list**,
a **finished list**, and the **AMART** (request table indexed by ID). Data
moves only between the SPM (a byte array standing in for the repurposed L2
slice / TPU VMEM slot ring) and far memory; register<->SPM traffic uses
:meth:`spm_read`/:meth:`spm_write` (the synchronous load/store half of the
paper's split).

The engine is *timed*: every request is scheduled on a
:class:`~repro.core.farmem.FarMemoryModel` and completes when the driver
advances the clock past its completion time. With :class:`InstantMemory` it
degenerates to a functional oracle used by the kernel tests.

ID batching (§4.2 metadata batching) is modeled: the ALSU-side list-vector
register caches up to ``batch_ids`` free/finished IDs, so steady-state
aload/getfin touch the (slower) ASMC lists only every ``batch_ids`` calls.
``batch_ids=1`` reproduces the paper's **AMU (DMA-mode)** ablation.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import EngineConfig
from repro.core.farmem import FarMemoryModel, InstantMemory

AMART_ENTRY_BYTES = 16   # §3.2: SPM addr, mem addr, status, impl. bits
LOAD, STORE = 0, 1


@dataclass
class Request:
    rid: int
    kind: int                 # LOAD | STORE
    spm_addr: int
    mem_addr: int
    size: int
    issue_time: float
    done_time: float = 0.0
    data: Optional[bytes] = None  # astore payload captured at issue


class SpmOverflow(ValueError):
    pass


class AsyncMemoryEngine:
    def __init__(self, config: EngineConfig,
                 far_memory: Optional[FarMemoryModel] = None,
                 backing: Optional[np.ndarray] = None):
        self.config = config
        self.far = far_memory or InstantMemory()
        # far-memory backing store (uint8); tests pass real arrays here
        self.mem = backing if backing is not None else np.zeros(1 << 20, np.uint8)
        meta_bytes = config.queue_length * AMART_ENTRY_BYTES
        if meta_bytes >= config.spm_bytes:
            raise SpmOverflow(
                f"queue_length={config.queue_length} needs {meta_bytes}B of "
                f"metadata but SPM is {config.spm_bytes}B")
        # data area = SPM minus the AMART/queue metadata area (queue_base..)
        self.spm_data_bytes = config.spm_bytes - meta_bytes
        self.spm = np.zeros(self.spm_data_bytes, np.uint8)
        # ASMC-side lists (IDs are 1-based; 0 is the failure code)
        self._free: Deque[int] = deque(range(1, config.queue_length + 1))
        self._finished: Deque[int] = deque()
        self.amart: Dict[int, Request] = {}
        self._pending: List[Tuple[float, int]] = []  # (done_time, rid)
        # ALSU list-vector registers (metadata batching caches)
        self._free_cache: Deque[int] = deque()
        self._fin_cache: Deque[int] = deque()
        self.now = 0.0
        # stats
        self.stats = {"aload": 0, "astore": 0, "getfin": 0, "getfin_empty": 0,
                      "alloc_fail": 0, "free_refills": 0, "fin_refills": 0}

    # ------------------------------------------------------------------ time
    def advance(self, now: float) -> None:
        """Move the clock; retire far-memory completions into the finished list."""
        self.now = max(self.now, now)
        while self._pending and self._pending[0][0] <= self.now:
            _, rid = heapq.heappop(self._pending)
            req = self.amart[rid]
            if req.kind == LOAD:
                src = self.mem[req.mem_addr:req.mem_addr + req.size]
                self.spm[req.spm_addr:req.spm_addr + req.size] = src
            else:
                self.mem[req.mem_addr:req.mem_addr + req.size] = np.frombuffer(
                    req.data, np.uint8)
            self._finished.append(rid)

    def drain(self) -> None:
        """Advance past every outstanding completion (functional mode helper)."""
        while self._pending:
            self.advance(self._pending[0][0])

    @property
    def outstanding(self) -> int:
        return len(self._pending)

    @property
    def next_completion_time(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    @property
    def finished_pending(self) -> int:
        return len(self._finished) + len(self._fin_cache)

    # ----------------------------------------------------------------- AMI
    def _alloc_id(self) -> int:
        if not self._free_cache:
            if not self._free:
                self.stats["alloc_fail"] += 1
                return 0
            # batch refill from the ASMC free list (one L2-latency round trip)
            n = min(self.config.batch_ids, len(self._free))
            self._free_cache.extend(self._free.popleft() for _ in range(n))
            self.stats["free_refills"] += 1
        return self._free_cache.popleft()

    def _issue(self, kind: int, spm_addr: int, mem_addr: int,
               size: Optional[int]) -> int:
        size = size or self.config.granularity
        if spm_addr + size > self.spm_data_bytes:
            raise SpmOverflow(f"SPM access [{spm_addr}, {spm_addr+size}) "
                              f"outside data area of {self.spm_data_bytes}B")
        rid = self._alloc_id()
        if rid == 0:
            return 0
        req = Request(rid, kind, spm_addr, mem_addr, size, self.now)
        if kind == STORE:
            req.data = self.spm[spm_addr:spm_addr + size].tobytes()
        req.done_time = self.far.issue(self.now, size)
        self.amart[rid] = req
        heapq.heappush(self._pending, (req.done_time, rid))
        self.stats["aload" if kind == LOAD else "astore"] += 1
        return rid

    def aload(self, spm_addr: int, mem_addr: int, size: Optional[int] = None) -> int:
        """Far memory -> SPM. Returns request ID, 0 if ID allocation failed."""
        return self._issue(LOAD, spm_addr, mem_addr, size)

    def astore(self, spm_addr: int, mem_addr: int, size: Optional[int] = None) -> int:
        """SPM -> far memory. Returns request ID, 0 if ID allocation failed."""
        return self._issue(STORE, spm_addr, mem_addr, size)

    def getfin(self) -> int:
        """Return a completed request ID (0 if none). Frees the ID."""
        self.advance(self.now)
        self.stats["getfin"] += 1
        if not self._fin_cache:
            if not self._finished:
                self.stats["getfin_empty"] += 1
                return 0
            n = min(self.config.batch_ids, len(self._finished))
            self._fin_cache.extend(self._finished.popleft() for _ in range(n))
            self.stats["fin_refills"] += 1
        rid = self._fin_cache.popleft()
        del self.amart[rid]
        self._free.append(rid)  # ID returns to the ASMC free list
        return rid

    # -------------------------------------------- config registers (Table 1)
    CFG_REGISTERS = ("granularity", "queue_base", "queue_length")

    def cfgrr(self, reg: str) -> int:
        """Read a configuration register into a 'GPR' (Table 1)."""
        if reg == "granularity":
            return self.config.granularity
        if reg == "queue_base":
            return self.spm_data_bytes        # metadata area starts past data
        if reg == "queue_length":
            return self.config.queue_length
        raise KeyError(reg)

    def cfgrw(self, reg: str, value: int) -> None:
        """Write a configuration register. `queue_length` re-initializes the
        metadata area (only legal with no requests outstanding — the paper's
        software contract for reconfiguration)."""
        import dataclasses
        if reg == "granularity":
            self.config = dataclasses.replace(self.config, granularity=value)
            return
        if reg == "queue_length":
            if self.outstanding or self.finished_pending or self.amart:
                raise RuntimeError("cannot resize queue with requests in flight")
            meta = value * AMART_ENTRY_BYTES
            if meta >= self.config.spm_bytes:
                raise SpmOverflow("queue_length metadata exceeds SPM")
            self.config = dataclasses.replace(self.config, queue_length=value)
            self.spm_data_bytes = self.config.spm_bytes - meta
            self.spm = self.spm[:self.spm_data_bytes].copy() if \
                self.spm.size > self.spm_data_bytes else np.concatenate(
                    [self.spm, np.zeros(self.spm_data_bytes - self.spm.size,
                                        np.uint8)])
            self._free = deque(range(1, value + 1))
            self._free_cache.clear()
            self._fin_cache.clear()
            self._finished.clear()
            return
        raise KeyError(reg)

    # ------------------------------------------------- synchronous SPM access
    def spm_write(self, spm_addr: int, data: bytes) -> None:
        arr = np.frombuffer(data, np.uint8)
        if spm_addr + arr.size > self.spm_data_bytes:
            raise SpmOverflow("spm_write outside data area")
        self.spm[spm_addr:spm_addr + arr.size] = arr

    def spm_read(self, spm_addr: int, size: int) -> bytes:
        if spm_addr + size > self.spm_data_bytes:
            raise SpmOverflow("spm_read outside data area")
        return self.spm[spm_addr:spm_addr + size].tobytes()

    # ----------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """ID conservation: every ID is in exactly one place."""
        ids = (list(self._free) + list(self._free_cache) + list(self._fin_cache)
               + list(self._finished) + [r for _, r in self._pending])
        in_flight_fin = set(self._finished) | set(self._fin_cache)
        pend = {r for _, r in self._pending}
        assert len(ids) == self.config.queue_length, (
            f"ID leak: {len(ids)} != {self.config.queue_length}")
        assert len(set(ids)) == len(ids), "duplicate ID"
        assert set(self.amart) == (pend | in_flight_fin), "AMART out of sync"
