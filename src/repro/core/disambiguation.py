"""Software-based memory disambiguation (§5.1).

A CAM-free conflict tracker for in-flight asynchronous requests: a multi-table
cuckoo hash *set* of active far-memory addresses. Unlike classic cuckoo
hashing, each hash function owns its own table (the paper's variation):
insertion tries table 0 with h0, then table 1 with h1, ... — no displacement
chains, so lookups/inserts are O(#tables) with tiny constants.

Each occupied slot carries a FIFO of waiters (coroutine handles) so that
conflicting requests serialize in program order, mirroring Listing 1:

    start_access(addr)  -> True if acquired, else the caller must suspend
    end_access(addr)    -> returns the next waiter to resume (or None)

Aliasing granularity is configurable (cache line by default): two accesses
conflict iff they touch the same aligned block.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Hashable, List, Optional

# 64-bit mix constants (splitmix64 finalizer) — cheap, well-dispersing
_MIX = (0xBF58476D1CE4E5B9, 0x94D049BB133111EB, 0xFF51AFD7ED558CCD,
        0xC4CEB9FE1A85EC53)
_MASK = (1 << 64) - 1


def _mix64(x: int, c: int) -> int:
    x &= _MASK
    x ^= x >> 30
    x = (x * c) & _MASK
    x ^= x >> 27
    x = (x * c) & _MASK
    x ^= x >> 31
    return x


@dataclass
class _Entry:
    addr: int
    holders: int = 1                      # current owner count (always 1 here)
    waiters: Deque[Hashable] = field(default_factory=deque)


class CuckooAddressSet:
    """Multi-table cuckoo hash set of active (in-flight) block addresses."""

    def __init__(self, slots_per_table: int = 1024, num_tables: int = 4,
                 block_bytes: int = 64):
        assert slots_per_table & (slots_per_table - 1) == 0, "power of two"
        self.num_tables = num_tables
        self.slots = slots_per_table
        self.block_shift = (block_bytes - 1).bit_length()
        self.tables: List[Dict[int, _Entry]] = [dict() for _ in range(num_tables)]
        # stats (Table 5's overhead accounting reads these)
        self.probes = 0
        self.inserts = 0
        self.conflicts = 0
        self.overflow_inserts = 0  # all tables collided -> spill dict
        self._spill: Dict[int, _Entry] = {}

    def _block(self, addr: int) -> int:
        return addr >> self.block_shift

    def _slot(self, block: int, table: int) -> int:
        return _mix64(block, _MIX[table % len(_MIX)]) & (self.slots - 1)

    def _find(self, block: int) -> Optional[_Entry]:
        for t in range(self.num_tables):
            self.probes += 1
            e = self.tables[t].get(self._slot(block, t))
            if e is not None and e.addr == block:
                return e
        return self._spill.get(block)

    # -- Listing 1 API -------------------------------------------------------
    def start_access(self, addr: int, waiter: Hashable = None) -> bool:
        """Try to acquire `addr`'s block. On conflict, enqueue `waiter` and
        return False (caller suspends). On success return True."""
        block = self._block(addr)
        entry = self._find(block)
        if entry is not None:
            self.conflicts += 1
            entry.waiters.append(waiter)
            return False
        self.inserts += 1
        for t in range(self.num_tables):
            slot = self._slot(block, t)
            if slot not in self.tables[t]:
                self.tables[t][slot] = _Entry(block)
                return True
        self.overflow_inserts += 1
        self._spill[block] = _Entry(block)
        return True

    def end_access(self, addr: int) -> Optional[Hashable]:
        """Release `addr`'s block. If someone is waiting, ownership transfers
        to the head waiter (entry stays); returns that waiter for resumption.
        Otherwise the entry is removed and None is returned."""
        block = self._block(addr)
        for t in range(self.num_tables):
            slot = self._slot(block, t)
            e = self.tables[t].get(slot)
            if e is not None and e.addr == block:
                if e.waiters:
                    return e.waiters.popleft()
                del self.tables[t][slot]
                return None
        e = self._spill.get(block)
        if e is None:
            raise KeyError(f"end_access on non-active block {block:#x}")
        if e.waiters:
            return e.waiters.popleft()
        del self._spill[block]
        return None

    def active_count(self) -> int:
        return sum(len(t) for t in self.tables) + len(self._spill)
