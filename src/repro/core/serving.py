"""Paged-KV serving as a first-class AMU workload (`paged_kv_serve`).

Multi-tenant LLM serving reduced to its far-memory skeleton: every request
gathers its KV pages — a hot shared-prefix pool that stays in local DRAM,
a per-tenant warm working set on CXL, and a cold pool across the switch —
folds them (the attention stand-in), and appends one new KV page. Requests
arrive on a seeded *open-loop* clock (Poisson, or a bursty diurnal trace)
via :class:`~repro.core.coroutines.WaitUntil`; each records its completion
latency with :class:`~repro.core.coroutines.Now`, so a run reports
per-request p50/p99/p999 alongside throughput
(:class:`~repro.amu.session.RunStats` ``req_*`` fields).

Three data planes, one page/tier layout:

* ``data_plane="ami"`` (default) — the paper's mechanism: ``coroutines``
  workers, asynchronous page gathers (scalar ``aload`` per page, or one
  ``aload_vec`` per request with ``vector=True``), MLP across requests.
* ``data_plane="sync"`` — the page-fault baseline ("A Tale of Two Paths"):
  ONE worker, a trap cost plus one *blocking* fetch per page, MLP ~= 1.
  The AMI-vs-sync latency ratio is the headline of the ``serve`` sweep.

:func:`serve_regions` builds the matching
:class:`~repro.core.farmem.FarMemoryRegion` list (same address split as the
builder), so ``AmuConfig(far=serve_regions())`` routes hot/warm/cold pages
through the PR 5 tiers. The workload also runs against the flat model (any
address resolves) for the smoke gate.

All randomness is drawn at BUILD time from the seed (page pools, per-request
tier composition, arrival times), so the instance — and therefore the
per-request latency trace — is pinned batch/scalar identical under the
existing differential discipline (engines are trace-identical under a fixed
scheduler; tests/test_serving.py).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.amu.commands import ctx
from repro.amu.config import FREQ_GHZ, far_region
from repro.amu.registry import workload as _workload
from repro.core.farmem import (BimodalTail, FarMemoryRegion, FaultModel,
                               LatencyDistribution)
from repro.core.workloads import (IterationProfile, WorkloadInstance, _cfg,
                                  _fit_spm)

#: cycles per microsecond at the simulated core clock
_CYC_PER_US = FREQ_GHZ * 1e3

# Default layout (shared by the builder and serve_regions): page counts per
# pool and the per-request gather mix. Scaled down like every workload, but
# keeping the structural character: a small very-hot shared prefix, a
# mid-size per-tenant warm set, a large cold tail.
PAGE_BYTES = 256
HOT_PAGES = 64
WARM_PAGES = 256
COLD_PAGES = 512
REQUESTS = 96
TIER_MIX = (0.5, 0.35, 0.15)        # P(page is hot / warm / cold)

_ARRIVAL_SEED_SALT = 101            # arrivals draw from their own stream


# ========================================================================
# Open-loop arrival processes (seeded, deterministic)
# ========================================================================
def poisson_arrivals(seed: int, n: int, rate_per_us: float) -> np.ndarray:
    """`n` open-loop Poisson arrival times in CYCLES (exponential gaps at
    `rate_per_us` requests/µs), strictly increasing, deterministic in
    `seed` (one Generator array fill — no order dependence to pin)."""
    if rate_per_us <= 0:
        raise ValueError(f"rate_per_us must be > 0, got {rate_per_us}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_us, size=n) * _CYC_PER_US
    return np.cumsum(gaps)


def bursty_arrivals(seed: int, n: int, rate_per_us: float,
                    burst_mult: float = 4.0, period_us: float = 8.0,
                    duty: float = 0.2) -> np.ndarray:
    """A bursty diurnal trace in CYCLES: a square-wave rate with a fraction
    `duty` of every `period_us` window at ``burst_mult x`` the base rate and
    the rest at the trough rate that preserves the mean. Implemented by
    time-rescaling unit-rate exponentials through the integrated rate (the
    inversion is exact for a piecewise-constant rate), so the draw is one
    Generator array fill and the trace is deterministic in `seed`."""
    if rate_per_us <= 0:
        raise ValueError(f"rate_per_us must be > 0, got {rate_per_us}")
    if not 0.0 < duty < 1.0:
        raise ValueError(f"duty must be in (0, 1), got {duty}")
    if burst_mult * duty >= 1.0:
        raise ValueError("burst carries the whole mean: need "
                         f"burst_mult*duty < 1, got {burst_mult * duty}")
    rng = np.random.default_rng(seed)
    unit = rng.exponential(1.0, size=n)          # unit-rate arrival masses
    peak = burst_mult * rate_per_us
    trough = (1.0 - duty * burst_mult) / (1.0 - duty) * rate_per_us
    # closed-form inversion of the integrated rate: every period carries
    # exactly `rate_per_us * period_us` mass (mean-preserving), so a
    # cumulative target splits into whole periods + a remainder that lands
    # either in the burst or the trough segment of its period
    targets = np.cumsum(unit)
    mass_period = rate_per_us * period_us
    mass_burst = peak * duty * period_us
    k = np.floor(targets / mass_period)
    rem = targets - k * mass_period
    t_us = np.where(
        rem <= mass_burst,
        k * period_us + rem / peak,
        k * period_us + duty * period_us + (rem - mass_burst) / trough)
    return t_us * _CYC_PER_US


def arrival_times(kind: str, seed: int, n: int, rate_per_us: float,
                  **kw) -> np.ndarray:
    """Dispatch on `kind` ("poisson" | "bursty")."""
    if kind == "poisson":
        return poisson_arrivals(seed, n, rate_per_us)
    if kind == "bursty":
        return bursty_arrivals(seed, n, rate_per_us, **kw)
    raise KeyError(f"unknown arrival process {kind!r}; "
                   "known: 'poisson', 'bursty'")


# ========================================================================
# Page/tier layout
# ========================================================================
def serve_regions(requests: int = REQUESTS, hot_pages: int = HOT_PAGES,
                  warm_pages: int = WARM_PAGES, cold_pages: int = COLD_PAGES,
                  page_bytes: int = PAGE_BYTES, local_us: float = 0.08,
                  cxl_us: float = 1.0, xswitch_us: float = 5.0,
                  tail: Optional[LatencyDistribution] = None,
                  link: Optional[str] = "switch",
                  faults: Optional[FaultModel] = None,
                  failover: Optional[str] = None) -> List[FarMemoryRegion]:
    """The tier list matching the builder's address split: hot pool + the
    per-request output pages in local DRAM, the warm pool on CXL, the cold
    pool across the switch (bimodal congestion tail by default), the two
    far tiers contending on one shared channel. Pass the same size knobs
    here and to the builder; ``AmuConfig(far=serve_regions(...))``.
    ``faults`` attaches a :class:`~repro.core.farmem.FaultModel` to the
    cross-switch tier (the fabric that actually flaps in production) and
    ``failover`` names its post-retry fallback tier (e.g. ``"cxl"``)."""
    if tail is None:
        tail = BimodalTail(0.05, 8.0)
    local_b = (hot_pages + requests) * page_bytes
    warm_b = warm_pages * page_bytes
    cold_b = cold_pages * page_bytes
    return [
        far_region("local", 0, local_b, local_us),
        far_region("cxl", local_b, warm_b, cxl_us, link=link),
        far_region("xswitch", local_b + warm_b, cold_b, xswitch_us,
                   distribution=tail, link=link, faults=faults,
                   failover=failover),
    ]


# ========================================================================
# The workload
# ========================================================================
@_workload("paged_kv_serve",
           profile=IterationProfile(insts=64, indep_loads=8, stores=1,
                                    mlp_cap=8, local_cycles=220),
           vector=True, request_level=True,
           description="multi-tenant paged-KV serving: open-loop arrivals, "
                       "tiered page gathers, per-request tail latency")
def build_paged_kv_serve(seed: int = 0, requests: int = REQUESTS,
                         pages_per_request: int = 8, tenants: int = 4,
                         hot_pages: int = HOT_PAGES,
                         warm_pages: int = WARM_PAGES,
                         cold_pages: int = COLD_PAGES,
                         page_bytes: int = PAGE_BYTES,
                         coroutines: int = 32,
                         arrival: str = "poisson",
                         rate_per_us: float = 2.0,
                         burst_mult: float = 4.0, period_us: float = 8.0,
                         duty: float = 0.2,
                         data_plane: str = "ami",
                         fault_insts: int = 180,
                         fault_cycles: float = 900.0,
                         compute_insts_per_page: int = 64,
                         sync_retries: int = 8,
                         vector: bool = False) -> WorkloadInstance:
    if data_plane not in ("ami", "sync"):
        raise KeyError(f"unknown data_plane {data_plane!r}; "
                       "known: 'ami', 'sync'")
    if page_bytes % 8:
        raise ValueError(f"page_bytes must be a multiple of 8: {page_bytes}")
    rng = np.random.default_rng(seed)
    page_words = page_bytes // 8

    # ------------------------------------------------- address space layout
    # [hot pool][per-request output pages] = local tier, then warm (CXL),
    # then cold (cross-switch) — the serve_regions split.
    hot_off = 0
    out_off = hot_pages * page_bytes
    warm_off = out_off + requests * page_bytes
    cold_off = warm_off + warm_pages * page_bytes
    total = cold_off + cold_pages * page_bytes
    pool = rng.integers(0, 1 << 63, size=total // 8, dtype=np.uint64)
    pool[out_off // 8:warm_off // 8] = 0        # output pages start blank
    mem = pool.view(np.uint8).copy()

    # ------------------------------------- per-request gathers and arrivals
    tier = rng.choice(3, size=(requests, pages_per_request), p=TIER_MIX)
    pick = rng.random(size=(requests, pages_per_request))
    page_addr = np.empty((requests, pages_per_request), np.int64)
    warm_per_tenant = warm_pages // tenants
    for r in range(requests):
        ten = r % tenants                        # tenant-private warm slice
        for j in range(pages_per_request):
            if tier[r, j] == 0:                  # hot: global shared prefix
                pg = int(pick[r, j] * hot_pages)
                page_addr[r, j] = hot_off + pg * page_bytes
            elif tier[r, j] == 1:                # warm: this tenant's set
                pg = ten * warm_per_tenant + int(pick[r, j] * warm_per_tenant)
                page_addr[r, j] = warm_off + pg * page_bytes
            else:                                # cold: anywhere
                pg = int(pick[r, j] * cold_pages)
                page_addr[r, j] = cold_off + pg * page_bytes
    out_addr = out_off + np.arange(requests, dtype=np.int64) * page_bytes
    arrive = arrival_times(arrival, seed + _ARRIVAL_SEED_SALT, requests,
                           rate_per_us, **(dict(burst_mult=burst_mult,
                                                period_us=period_us,
                                                duty=duty)
                                           if arrival == "bursty" else {}))

    lat = np.full(requests, -1.0)                # completion - arrival, cycles
    pool_words = pool.copy()                     # snapshot for the oracle

    # ---------------------------------------------------------- data planes
    def fold(pages_u64: np.ndarray) -> np.ndarray:
        """The attention stand-in: XOR-fold the gathered pages into the
        appended KV page (schedule-independent, cheap to oracle)."""
        return np.bitwise_xor.reduce(pages_u64.reshape(-1, page_words),
                                     axis=0)

    def sync_fallback(spm: int, addr: int, status):
        """Degradation mode: the AMI plane reported a final failure (after
        the scheduler's retries/failover), so fall back to the synchronous
        page-fault plane — pay the trap cost and re-fetch, up to
        `sync_retries` blocking attempts. Returns the final status (0 once
        a fetch lands); a still-failing page is dropped from the fold so
        the request completes degraded instead of wedging the worker."""
        tries = 0
        while status and tries < sync_retries:
            yield ctx.cost(insts=fault_insts, cycles=fault_cycles)
            status = yield ctx.aload(spm, addr, page_bytes)
            tries += 1
        return status

    def ami_task(c: int):
        spm = c * page_bytes
        for r in range(c, requests, coroutines):
            yield ctx.wait_until(arrive[r])
            acc = np.zeros(page_words, np.uint64)
            for addr in page_addr[r]:
                st = yield ctx.aload(spm, int(addr), page_bytes)
                if st:                           # None/0 on the happy path
                    st = yield from sync_fallback(spm, int(addr), st)
                    if st:
                        continue                 # page lost: degraded fold
                data = yield ctx.spm_read(spm, page_bytes)
                acc = acc ^ data.view(np.uint64)
                yield ctx.cost(insts=compute_insts_per_page)
            yield ctx.spm_write(spm, acc)
            yield ctx.astore(spm, int(out_addr[r]), page_bytes)
            t_end = yield ctx.now()
            lat[r] = t_end - arrive[r]

    def ami_vtask(c: int):
        base = c * pages_per_request * page_bytes
        slots = base + np.arange(pages_per_request) * page_bytes
        for r in range(c, requests, coroutines):
            yield ctx.wait_until(arrive[r])
            st = yield ctx.aload_vec(slots, page_addr[r], page_bytes,
                                     wait=True)
            data = yield ctx.spm_read(base, pages_per_request * page_bytes)
            if st is None or not np.any(st):     # zero-fault / all lanes OK
                acc = fold(data.view(np.uint64))
            else:                                # per-lane degradation
                ok = np.ones(pages_per_request, bool)
                for j in np.flatnonzero(st):
                    s2 = yield from sync_fallback(
                        int(slots[j]), int(page_addr[r, j]), int(st[j]))
                    ok[j] = not s2
                data = yield ctx.spm_read(base,
                                          pages_per_request * page_bytes)
                pages = data.view(np.uint64).reshape(-1, page_words)
                acc = (np.bitwise_xor.reduce(pages[ok], axis=0) if ok.any()
                       else np.zeros(page_words, np.uint64))
            yield ctx.cost(insts=compute_insts_per_page * pages_per_request)
            yield ctx.spm_write(base, acc)
            yield ctx.astore(base, int(out_addr[r]), page_bytes)
            t_end = yield ctx.now()
            lat[r] = t_end - arrive[r]

    def sync_task():
        """Page-fault baseline: one worker, a trap + blocking fetch per
        page — no memory-level parallelism anywhere."""
        spm = 0
        for r in range(requests):                # arrivals are sorted
            yield ctx.wait_until(arrive[r])
            acc = np.zeros(page_words, np.uint64)
            for addr in page_addr[r]:
                yield ctx.cost(insts=fault_insts, cycles=fault_cycles)
                st = yield ctx.aload(spm, int(addr), page_bytes)
                if st:
                    st = yield from sync_fallback(spm, int(addr), st)
                    if st:
                        continue
                data = yield ctx.spm_read(spm, page_bytes)
                acc = acc ^ data.view(np.uint64)
                yield ctx.cost(insts=compute_insts_per_page)
            yield ctx.spm_write(spm, acc)
            yield ctx.astore(spm, int(out_addr[r]), page_bytes)
            t_end = yield ctx.now()
            lat[r] = t_end - arrive[r]

    if data_plane == "sync":
        use_vector = False
        tasks = [sync_task()]
        window_bytes, qlen = page_bytes, 256
    elif vector:
        use_vector = True
        coroutines = min(coroutines, requests)
        tasks = [ami_vtask(c) for c in range(coroutines)]
        window_bytes = coroutines * pages_per_request * page_bytes
        qlen = min(2048, max(256, 2 * coroutines * pages_per_request))
    else:
        use_vector = False
        coroutines = min(coroutines, requests)
        tasks = [ami_task(c) for c in range(coroutines)]
        window_bytes = coroutines * page_bytes
        qlen = min(2048, max(256, 2 * coroutines))

    # ------------------------------------------------------------- oracle
    expect = np.empty((requests, page_words), np.uint64)
    for r in range(requests):
        idx = page_addr[r] // 8
        gathered = np.stack([pool_words[i:i + page_words] for i in idx])
        expect[r] = np.bitwise_xor.reduce(gathered, axis=0)

    def verify(mem_out: np.ndarray) -> bool:
        got = mem_out[out_off:out_off + requests * page_bytes] \
            .view(np.uint64).reshape(requests, page_words)
        if not np.array_equal(got, expect):
            return False
        # every request completed after (never before) its arrival
        return bool(np.all(lat >= 0.0))

    cfg = _cfg(page_bytes, queue_length=qlen,
               spm_bytes=_fit_spm(window_bytes, qlen))
    return WorkloadInstance("paged_kv_serve", mem, tasks, requests, cfg,
                            verify, vector=use_vector,
                            request_latency_cycles=lat)
