"""Cycle-approximate performance model of the paper's four configurations.

The paper evaluates on Gem5 (Table 2: 3 GHz 6-wide OoO, 512 ROB, 192 LSQ,
48 MSHRs). We reproduce the *performance claims* with a two-part model:

* **Baseline / CXL-Ideal(+BOP)** — an out-of-order *window model*
  (`simulate_window`): iterations of a workload's
  :class:`~repro.core.workloads.IterationProfile` flow through a reorder
  window. An iteration may begin issuing only when the iteration
  `window_iters` back has retired (ROB occupancy), far loads contend for
  MSHRs (modeled as the far-memory channel's `max_inflight`), stores drain
  through a finite store buffer, and dependent (chase) loads serialize.
  CXL-Ideal raises MSHRs to 256 everywhere and adds a best-offset prefetcher
  that covers a fraction of loads for `sequential=True` workloads.

* **AMU / AMU (DMA-mode)** — not a model at all: the *actual* coroutine
  ports of the benchmarks execute against the timed engine through a
  :class:`repro.amu.AmuSession`. Execution time, IPC, and MLP fall out of
  the run. DMA-mode sets `batch_ids=1` and the per-request
  descriptor/doorbell cost, reproducing the external-engine ablation. The
  session's :class:`repro.amu.AmuConfig` picks the scalar per-event oracle
  (:class:`~repro.core.engine.AsyncMemoryEngine`) or the vectorized batched
  path (:class:`~repro.core.engine.BatchedAsyncMemoryEngine` +
  :class:`~repro.core.coroutines.BatchScheduler`), which are proven
  trace-equivalent by tests/test_batched_engine.py.

Calibration: the free constants (instruction counts per iteration, coroutine
switch cost, store-buffer depth) were tuned once against the paper's headline
numbers (geo-mean 2.42x @1us; GUPS 26.86x @5us with >130 MLP) and then frozen;
EXPERIMENTS.md reports the residuals.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.amu import REGISTRY, AmuConfig, AmuSession
from repro.amu.config import FREQ_GHZ, LINE, far_config
from repro.core.farmem import FarMemoryModel
from repro.core.workloads import IterationProfile  # noqa: F401 (re-export +
#                                                    registry population)


@dataclass(frozen=True)
class CoreConfig:
    """Gem5 baseline configuration (Table 2)."""
    issue_width: int = 6
    rob: int = 512
    lsq: int = 192
    mshr: int = 48
    store_buffer: int = 56
    l2_hit_cycles: float = 10.0
    local_dram_cycles: float = 240.0     # ~80 ns local DRAM
    pf_coverage: float = 0.0             # BOP prefetch coverage (CXL-Ideal)
    pf_mshr_share: float = 0.5           # prefetches consume MSHR bandwidth


BASELINE_CORE = CoreConfig()
CXL_IDEAL_CORE = CoreConfig(mshr=256, pf_coverage=0.8)


# =========================================================================
# Baseline OoO window model
# =========================================================================
def simulate_window(profile: IterationProfile, iters: int, latency_us: float,
                    core: CoreConfig = BASELINE_CORE,
                    seed: int = 0) -> Dict[str, float]:
    """Window model of a synchronous load/store loop.

    Iterations overlap up to the reorder-window depth (ROB/LSQ-bounded);
    within an iteration, chase loads serialize and independent loads overlap.
    Individual completions are order-independent (t + latency); global
    resource limits are applied as Little's-law lower bounds on total time:
    sustained far-op concurrency <= `mlp_cap` (or the window-derived limit,
    capped by MSHRs) and link bandwidth over total bytes.
    """
    rng = np.random.default_rng(seed)
    cfg = far_config(latency_us)
    lat = cfg.base_latency_cycles
    serial = LINE / cfg.bandwidth_bytes_per_cycle

    mem_ops = profile.chase + profile.indep_loads + profile.stores
    iter_insts = profile.insts + 2 * mem_ops       # addr-gen + the op itself

    if profile.mlp_cap:
        # Additive Little's-law mode (fitted against Table 4): serialized
        # core/local work plus far-memory occupancy at the effective
        # concurrency cap. CXL-Ideal's extra MSHRs scale the cap; its BOP
        # prefetcher covers sequential loads (they become near-L2 hits but
        # still traverse the link -> bandwidth term).
        cap = profile.mlp_cap * (core.mshr / BASELINE_CORE.mshr)
        cap = min(cap, core.mshr)
        loads = (profile.chase + profile.indep_loads) * iters
        covered = 0.0
        if profile.sequential and core.pf_coverage:
            covered = loads * core.pf_coverage
        far_loads = (loads - covered) * (1.0 - profile.local_frac)
        far_ops_f = far_loads + profile.stores * iters
        far_bytes_f = (far_loads + covered * (1.0 - profile.local_frac)
                       + profile.stores * iters) * LINE
        core_total = iters * (iter_insts / core.issue_width
                              + profile.local_cycles)
        total = core_total + far_ops_f * lat / cap
        total = max(total, far_bytes_f / cfg.bandwidth_bytes_per_cycle)
        insts = iters * iter_insts
        return {
            "cycles": total,
            "insts": insts,
            "ipc": insts / max(total, 1e-9),
            "mlp": far_ops_f * lat / max(total, 1e-9),
            "requests": int(far_ops_f),
            "bytes": int(far_bytes_f),
            "disamb_frac": 0.0,
        }

    window = max(1, min(int(core.rob // max(iter_insts, 1)),
                        int(core.lsq // max(mem_ops, 1e-9))))

    done: List[float] = []           # retire time per iteration
    store_done: List[float] = []     # completion times of issued stores
    core_t = 0.0
    issue_cycles = iter_insts / core.issue_width
    n_stores_frac = 0.0
    far_ops = 0
    far_bytes = 0

    def load_latency(t: float) -> float:
        """One demand load issued at t; returns its completion time."""
        nonlocal far_ops, far_bytes
        if profile.local_frac and rng.random() < profile.local_frac:
            return t + core.l2_hit_cycles
        if (profile.sequential and core.pf_coverage
                and rng.random() < core.pf_coverage):
            # covered by the L2 best-offset prefetcher: near-L2 hit; the
            # prefetch still moved the line over the link (bandwidth bound)
            far_bytes += LINE
            return t + core.l2_hit_cycles
        far_ops += 1
        far_bytes += LINE
        return t + serial + lat

    for i in range(iters):
        start = core_t
        if i >= window:
            start = max(start, done[i - window])   # ROB head must retire
        # store buffer back-pressure: the (i - SB)'th store must have drained
        if len(store_done) > core.store_buffer:
            start = max(start, store_done[len(store_done)
                                          - core.store_buffer - 1])
        core_t = start + issue_cycles + profile.local_cycles
        t = start + issue_cycles * 0.5 + profile.local_cycles
        chase_t = t
        for _ in range(int(profile.chase)):
            chase_t = load_latency(chase_t)
        indep_t = t
        for _ in range(int(profile.indep_loads)):
            indep_t = max(indep_t, load_latency(t))
        iter_done = max(chase_t, indep_t, core_t)
        n_stores_frac += profile.stores
        while n_stores_frac >= 1.0:
            far_ops += 1
            far_bytes += LINE
            store_done.append(iter_done + serial + lat)
            n_stores_frac -= 1.0
        done.append(iter_done)

    total = max(done[-1], store_done[-1] if store_done else 0.0)
    # Little's-law resource bounds
    mlp_cap = profile.mlp_cap or min(window * max(mem_ops, 1), core.mshr)
    total = max(total,
                far_ops * lat / max(mlp_cap, 1e-9),         # sustained MLP
                far_bytes / cfg.bandwidth_bytes_per_cycle)  # link bandwidth
    insts = iters * iter_insts
    return {
        "cycles": total,
        "insts": insts,
        "ipc": insts / max(total, 1e-9),
        "mlp": far_ops * lat / max(total, 1e-9),
        "requests": far_ops,
        "bytes": far_bytes,
        "disamb_frac": 0.0,
    }


# =========================================================================
# Software (group) prefetching model — Table 4's PF columns
# =========================================================================
def simulate_group_prefetch(profile: IterationProfile, iters: int,
                            latency_us: float, group: int,
                            core: CoreConfig = BASELINE_CORE,
                            seed: int = 0) -> Dict[str, float]:
    """Group prefetching [16]: issue `group` prefetches, then execute the
    group's iterations. Prefetches are asynchronous but (a) consume MSHRs,
    (b) have no completion notification — the demand access stalls if the
    prefetch hasn't landed (late prefetch), and re-fetches if it was evicted
    (early prefetch, pressure-dependent)."""
    rng = np.random.default_rng(seed)
    chan = FarMemoryModel(far_config(latency_us, max_inflight=core.mshr))
    loads_per_iter = profile.chase + profile.indep_loads
    iter_insts = profile.insts + 2 * (loads_per_iter + profile.stores) + 2
    t = 0.0
    insts = 0.0
    # eviction probability grows once the group overflows cache/MSHR capacity
    evict_p = max(0.0, min(0.9, (group - core.mshr) / max(group, 1)))
    for g0 in range(0, iters, group):
        g = min(group, iters - g0)
        ready = []
        for k in range(g):
            t += 1.0 / core.issue_width          # prefetch instruction
            insts += 1
            ready.append(chan.issue(t, LINE * loads_per_iter))
        for k in range(g):
            t += iter_insts / core.issue_width
            insts += iter_insts
            if rng.random() < evict_p:
                t = chan.issue(t, LINE)          # re-fetch on eviction
            else:
                t = max(t, ready[k])             # late prefetch stall
            if profile.stores:
                chan.issue(t, LINE)
    return {"cycles": t, "insts": insts, "ipc": insts / max(t, 1e-9),
            "mlp": chan.avg_mlp(t), "requests": chan.requests,
            "bytes": chan.bytes_moved, "disamb_frac": 0.0}


# =========================================================================
# Top-level: one call per (workload, config, latency)
# =========================================================================
CONFIG_NAMES = ("baseline", "cxl-ideal", "amu", "amu-dma")


def run(workload: str, config: str, latency_us: float,
        seed: int = 0, amu: Optional[AmuConfig] = None,
        **kw) -> Dict[str, float]:
    """One (workload, config, latency) data point.

    ``baseline``/``cxl-ideal`` drive the OoO window model; the ``amu*``
    configs run the real coroutine port through an :class:`AmuSession`.
    `amu` is the base :class:`AmuConfig` for those runs (defaults to the
    scalar per-event oracle); remaining ``kw`` are derived onto it
    (``engine=``, ``vector=``, ``verify=``, ``engine_config=``, ...), so
    existing keyword call sites keep working unchanged.
    """
    wd = REGISTRY[workload]
    if config == "baseline":
        inst_units = wd.build(seed).units
        out = simulate_window(wd.profile, inst_units, latency_us,
                              BASELINE_CORE, seed=seed)
    elif config == "cxl-ideal":
        inst_units = wd.build(seed).units
        out = simulate_window(wd.profile, inst_units, latency_us,
                              CXL_IDEAL_CORE, seed=seed)
    elif config in ("amu", "amu-dma", "amu-llvm"):
        cfg = (amu or AmuConfig(engine="scalar")).derive(
            latency_us=latency_us, seed=seed,
            dma_mode=config == "amu-dma",
            llvm_mode=config == "amu-llvm", **kw)
        with AmuSession(cfg) as session:
            out = session.run(workload).to_dict()
    else:
        raise KeyError(config)
    out["config"] = config
    out["workload"] = workload
    out["latency_us"] = latency_us
    out["us"] = out["cycles"] / (FREQ_GHZ * 1e3)
    return out


# ------------------------------------------------------------- power model
@dataclass(frozen=True)
class PowerModel:
    """McPAT-style first-order energy accounting (Fig 11)."""
    static_w: float = 1.2           # core + L2 leakage
    epi_nj: float = 0.35            # energy per retired instruction
    epr_nj: float = 2.0             # energy per far-memory request (I/O)
    spm_nj: float = 0.15            # per SPM touch (AMU metadata upkeep)

    def power(self, stats: Dict[str, float], spm_touches: float = 0.0) -> float:
        t_s = stats["cycles"] / (FREQ_GHZ * 1e9)
        dyn = (stats["insts"] * self.epi_nj + stats["requests"] * self.epr_nj
               + spm_touches * self.spm_nj) * 1e-9
        return self.static_w + dyn / max(t_s, 1e-12)
