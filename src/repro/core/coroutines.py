"""Coroutine programming framework (§5.2) with a timed runtime.

Python generators stand in for the paper's C++20 coroutines. A task yields
*commands*; the scheduler implements Figure 4's runtime loop:

  1. a task yields :class:`Aload`/:class:`Astore` -> the engine issues the
     request (the instruction retires immediately), the task suspends on the
     returned ID;
  2. the event loop executes ``getfin`` to fetch a completed ID;
  3. the task waiting on that ID is resumed;
  4. the task reads/writes the returned bytes in SPM with synchronous
     :class:`SpmRead`/:class:`SpmWrite` (short, fixed latency — no misses).

Vector commands (:class:`AloadVec`/:class:`AstoreVec` + :class:`AwaitRids`)
issue a whole request vector per generator hop: the scheduler dispatches them
through the engine's ``aload_batch``/``astore_batch`` entry points (true
vector path on `BatchedAsyncMemoryEngine`, scalar-issue loop on the oracle)
and charges ONE amortized issue + ID-batch cost per vector — the §4.2
speculative ID pre-allocation applied at the framework layer. This is what
removes the per-request Python coroutine round-trip from the loop-parallel
workload ports.

:class:`Acquire`/:class:`Release` wrap the software memory-disambiguation set
(Listing 1): conflicting tasks suspend and are resumed in FIFO order when the
owner releases the block.

The scheduler keeps a cycle clock and instruction counter so AMU-mode
execution times / IPC / MLP come out of *actually running* the workloads
against the timed engine — this is what `benchmarks/fig8..fig10` drive.

Cost model (instructions per operation; 6-wide issue, 3 GHz — Table 2):
calibrated constants below; the DMA-mode ablation inflates the per-request
cost exactly where the paper says external engines pay it (descriptor setup,
doorbell, no speculative ID batching).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, Generator, Iterable, Optional

import numpy as np

from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import AsyncEngineBase


# ---------------------------------------------------------------------- cost
@dataclass(frozen=True)
class CostModel:
    """Per-operation costs, calibrated against Table 4 (AMU ~= baseline at
    0.1 us <=> ~250 core cycles per awaited memory op: coroutine frame
    save/restore + scheduler bookkeeping + getfin loop + SPM (L2) latency)."""
    issue_width: int = 6
    ami_issue_insts: int = 8       # aload/astore + address generation + ID mv
    getfin_insts: int = 8          # poll + dispatch branch
    switch_insts: int = 40         # coroutine suspend+resume instructions
    switch_stall_cycles: float = 100.0  # dependent-chain stalls per switch
    spm_access_cycles: float = 15.0  # L2-latency SPM touch (Table 2)
    spm_byte_cycles: float = 0.25  # per-byte SPM streaming cost (reads the
                                   # DMA'd block out of L2 with dependent ops)
    refill_cycles: float = 20.0    # ALSU<->ASMC list round trip (batched)
    # software disambiguation (Listing 1): cuckoo probe + insert / remove +
    # waiter wakeup. Cache-resident hash tables -> tens of cycles.
    acquire_insts: int = 25
    acquire_stall_cycles: float = 5.0
    release_insts: int = 20
    release_stall_cycles: float = 3.0
    # DMA-mode extras (external-engine ablation: descriptor setup + MMIO
    # doorbell over the NoC, non-speculative issue)
    dma_descriptor_insts: int = 60
    dma_serialize_cycles: float = 180.0
    # vector AMI commands (AloadVec/AstoreVec): the paper's speculative ID
    # pre-allocation means a whole vector pays ONE issue + ID-batch cost
    # (ami_issue_insts, plus refill_cycles per actual list refill) and only a
    # small per-element marginal: address append into the request vector.
    vec_elem_insts: float = 1.5

    def insts_to_cycles(self, insts: float) -> float:
        return insts / self.issue_width


# ------------------------------------------------------------------ commands
@dataclass(frozen=True)
class Aload:
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class Astore:
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class AloadNoWait:
    """Issue an aload and continue executing (returns the request ID to the
    task immediately); pair with AwaitRid to suspend on completion later."""
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class AstoreNoWait:
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class AwaitRid:
    rid: int


@dataclass(frozen=True, eq=False)
class AloadVec:
    """Vectorized aload: issue ``len(spm)`` far->SPM requests as ONE AMI
    vector command (§4.2 metadata batching at the framework level). `spm` and
    `mem` are parallel sequences (lists/tuples/numpy arrays) of SPM offsets
    and far-memory addresses; `size` is the shared granularity (None -> the
    engine's configured granularity). The task resumes immediately with a
    tuple of wait tokens — pair with :class:`AwaitRids` to suspend until the
    whole vector has completed."""
    spm: object
    mem: object
    size: Optional[int] = None


@dataclass(frozen=True, eq=False)
class AstoreVec:
    """Vectorized astore (SPM -> far memory); see :class:`AloadVec`."""
    spm: object
    mem: object
    size: Optional[int] = None


@dataclass(frozen=True, eq=False)
class AwaitRids:
    """Suspend until EVERY token in `rids` has completed (one coroutine
    resume total — the amortized counterpart of N AwaitRid hops)."""
    rids: tuple


@dataclass(frozen=True)
class Acquire:     # software disambiguation: start_access
    addr: int


@dataclass(frozen=True)
class Release:     # software disambiguation: end_access
    addr: int


@dataclass(frozen=True)
class SpmWrite:
    spm: int
    data: bytes


@dataclass(frozen=True)
class SpmRead:
    spm: int
    size: int


@dataclass(frozen=True)
class Cost:        # plain compute between memory ops
    insts: float = 0.0
    cycles: float = 0.0


Task = Generator  # yields commands, receives command results


class DeadlockError(RuntimeError):
    pass


class Scheduler:
    def __init__(self, engine: AsyncEngineBase,
                 cost: CostModel = CostModel(),
                 disambiguator: Optional[CuckooAddressSet] = None,
                 dma_mode: bool = False):
        self.engine = engine
        self.cost = cost
        self.disamb = disambiguator
        self.dma_mode = dma_mode
        self.t = 0.0                       # core clock, cycles
        self.insts = 0.0                   # retired instructions
        self.disamb_cycles = 0.0           # time inside start/end_access
        self._ready: Deque[Task] = deque()
        self._alloc_parked: Deque[tuple] = deque()  # (task, command)
        self._results: Dict[int, object] = {}       # id(task) -> pending send
        # request IDs recycle after getfin, so the scheduler tracks each issue
        # with a unique token: rid -> token while in flight, and tasks wait
        # on tokens.
        self._tok = 0
        self._rid_tok: Dict[int, int] = {}
        self._waiting_tok: Dict[int, Task] = {}
        self._unclaimed: set = set()                # completed tokens, no waiter
        # vector-command state: tokens already issued for a parked vector
        # (id(task) -> list), and AwaitRids countdowns (id(task) -> remaining)
        self._vec_acc: Dict[int, list] = {}
        self._wait_count: Dict[int, int] = {}
        self._live = 0

    # --------------------------------------------------------------- helpers
    def _tick_insts(self, insts: float) -> None:
        self.insts += insts
        self.t += self.cost.insts_to_cycles(insts)

    def _issue(self, task: Task, cmd) -> None:
        """Execute an Aload/Astore[-NoWait] or vector issue command."""
        if isinstance(cmd, (AloadVec, AstoreVec)):
            return self._issue_vec(task, cmd)
        c = self.cost
        self._tick_insts(c.ami_issue_insts)
        if self.dma_mode:
            self._tick_insts(c.dma_descriptor_insts)
            self.t += c.dma_serialize_cycles
        self.engine.advance(self.t)
        refills = self.engine.stats["free_refills"]
        if isinstance(cmd, (Aload, AloadNoWait)):
            rid = self.engine.aload(cmd.spm, cmd.mem, cmd.size)
        else:
            rid = self.engine.astore(cmd.spm, cmd.mem, cmd.size)
        if self.engine.stats["free_refills"] != refills:
            self.t += c.refill_cycles      # batched ID fetch round trip
        if rid == 0:
            self._alloc_parked.append((task, cmd))  # queue full: retry later
            return
        self._tok += 1
        self._rid_tok[rid] = self._tok
        if isinstance(cmd, (AloadNoWait, AstoreNoWait)):
            self._results[id(task)] = self._tok  # token back, keep running
            self._ready.append(task)
        else:
            self._waiting_tok[self._tok] = task

    def _issue_vec(self, task: Task, cmd) -> None:
        """Execute an AloadVec/AstoreVec for `task`: one amortized issue cost,
        one engine batch call. If the ID pool exhausts mid-vector, the
        remainder parks (retried as completions free IDs) and the task only
        resumes once every element has been issued."""
        c = self.cost
        n = len(cmd.spm)
        acc = self._vec_acc.pop(id(task), [])
        if n == 0:
            self._results[id(task)] = tuple(acc)
            self._ready.append(task)
            return
        # speculative ID pre-allocation: one issue + ID-batch cost per vector
        self._tick_insts(c.ami_issue_insts + c.vec_elem_insts * n)
        if self.dma_mode:
            # external engines pay descriptor setup + doorbell per request
            self._tick_insts(c.dma_descriptor_insts * n)
            self.t += c.dma_serialize_cycles * n
        self.engine.advance(self.t)
        refills = self.engine.stats["free_refills"]
        if isinstance(cmd, AloadVec):
            rids = self.engine.aload_batch(cmd.spm, cmd.mem, self._vec_sizes(cmd, n))
        else:
            rids = self.engine.astore_batch(cmd.spm, cmd.mem, self._vec_sizes(cmd, n))
        self.t += c.refill_cycles * (self.engine.stats["free_refills"] - refills)
        k = int(np.count_nonzero(rids))     # allocation fails as a suffix
        for rid in rids[:k]:
            self._tok += 1
            self._rid_tok[int(rid)] = self._tok
            acc.append(self._tok)
        if k < n:
            rest = type(cmd)(cmd.spm[k:], cmd.mem[k:], cmd.size)
            self._vec_acc[id(task)] = acc
            self._alloc_parked.append((task, rest))
        else:
            self._results[id(task)] = tuple(acc)
            self._ready.append(task)

    @staticmethod
    def _vec_sizes(cmd, n: int):
        return None if cmd.size is None else np.full(n, cmd.size, np.int64)

    def _run_task(self, task: Task, send_value=None) -> None:
        """Resume `task`, process the command it yields (if not finished)."""
        c = self.cost
        try:
            cmd = task.send(send_value)
        except StopIteration:
            self._live -= 1
            return
        if isinstance(cmd, (Aload, Astore, AloadNoWait, AstoreNoWait,
                            AloadVec, AstoreVec)):
            self._issue(task, cmd)
        elif isinstance(cmd, AwaitRid):
            if cmd.rid in self._unclaimed:       # cmd.rid is the issue token
                self._unclaimed.discard(cmd.rid)
                self._ready.append(task)
            else:
                self._waiting_tok[cmd.rid] = task
        elif isinstance(cmd, AwaitRids):
            remaining = 0
            for tok in cmd.rids:
                if tok in self._unclaimed:
                    self._unclaimed.discard(tok)
                else:
                    self._waiting_tok[tok] = task
                    remaining += 1
            if remaining:
                self._wait_count[id(task)] = remaining
            else:
                self._ready.append(task)
        elif isinstance(cmd, Cost):
            self._tick_insts(cmd.insts)
            self.t += cmd.cycles
            self._ready.append(task)
        elif isinstance(cmd, SpmWrite):
            self.t += c.spm_access_cycles + c.spm_byte_cycles * len(cmd.data)
            self._tick_insts(1 + len(cmd.data) // 8)
            self.engine.spm_write(cmd.spm, cmd.data)
            self._ready.append(task)
        elif isinstance(cmd, SpmRead):
            self.t += c.spm_access_cycles + c.spm_byte_cycles * cmd.size
            self._tick_insts(1 + cmd.size // 8)
            self._results[id(task)] = self.engine.spm_read(cmd.spm, cmd.size)
            self._ready.append(task)
        elif isinstance(cmd, Acquire):
            assert self.disamb is not None, "no disambiguator configured"
            t0 = self.t
            self._tick_insts(c.acquire_insts)  # hash + probe (Listing 1 l.7)
            self.t += c.acquire_stall_cycles
            ok = self.disamb.start_access(cmd.addr, waiter=task)
            self.disamb_cycles += self.t - t0
            if ok:
                self._ready.append(task)
            # else: suspended; Release will requeue it
        elif isinstance(cmd, Release):
            assert self.disamb is not None
            t0 = self.t
            self._tick_insts(c.release_insts)
            self.t += c.release_stall_cycles
            waiter = self.disamb.end_access(cmd.addr)
            self.disamb_cycles += self.t - t0
            if waiter is not None:
                self._ready.append(waiter)
            self._ready.append(task)
        else:
            raise TypeError(f"unknown command {cmd!r}")

    def _dispatch_fin(self, rid: int) -> None:
        """Route a completed request ID to its awaiting task (if any). A task
        suspended on AwaitRids only resumes — and only pays the coroutine
        switch once — when its LAST outstanding token completes."""
        tok = self._rid_tok.pop(rid)
        task = self._waiting_tok.pop(tok, None)
        if task is None:
            self._unclaimed.add(tok)
            return
        cnt = self._wait_count.get(id(task))
        if cnt is not None:
            if cnt > 1:
                self._wait_count[id(task)] = cnt - 1
                return                       # still waiting on more tokens
            del self._wait_count[id(task)]
        self._tick_insts(self.cost.switch_insts)  # resume the awaiter
        self.t += self.cost.switch_stall_cycles
        self._ready.append(task)

    def _idle_until_completion(self) -> None:
        """Nothing runnable: validate liveness and advance to the next
        completion (shared deadlock detection for both runtime loops)."""
        if not (self._waiting_tok or self._alloc_parked):
            raise DeadlockError("live tasks but none ready/waiting")
        next_done = self.engine.next_completion_time
        if next_done is None:
            if self.engine.finished_pending:
                return                     # drain via getfin next round
            raise DeadlockError(
                f"{len(self._waiting_tok)} waiting, "
                f"{len(self._alloc_parked)} parked, none outstanding")
        self.t = max(self.t, next_done)
        self.engine.advance(self.t)

    # ------------------------------------------------------------------ API
    def spawn(self, task: Task) -> None:
        self._live += 1
        self._ready.append(task)

    def run(self, tasks: Optional[Iterable[Task]] = None) -> dict:
        """Drive all tasks to completion; returns timing/throughput stats."""
        c = self.cost
        for task in tasks or ():
            self.spawn(task)
        while self._live > 0:
            # event loop: poll completions first (Fig 4 step 3)
            if (self._waiting_tok or self._alloc_parked
                    or self.engine.outstanding or self.engine.finished_pending):
                self.engine.advance(self.t)
                self._tick_insts(c.getfin_insts)
                rid = self.engine.getfin()
                if rid:
                    self._dispatch_fin(rid)
                    # freed an ID: a parked task can retry its issue
                    if self._alloc_parked:
                        ptask, pcmd = self._alloc_parked.popleft()
                        self._issue(ptask, pcmd)
            if self._ready:
                task = self._ready.popleft()
                self._run_task(task, self._results.pop(id(task), None))
            elif self._live > 0:
                self._idle_until_completion()
        return self.summary()

    def summary(self) -> dict:
        far = self.engine.far
        return {
            "cycles": self.t,
            "insts": self.insts,
            "ipc": self.insts / max(self.t, 1e-9),
            "mlp": far.avg_mlp(self.t),
            "requests": far.requests,
            "bytes": far.bytes_moved,
            "disamb_cycles": self.disamb_cycles,
            "disamb_frac": self.disamb_cycles / max(self.t, 1e-9),
        }


class BatchScheduler(Scheduler):
    """Batch-stepped runtime loop (§4.2 metadata batching applied to the host
    model): each *epoch* drains ALL currently-finished IDs in one
    ``getfin_all`` sweep, resumes every awaiter, then steps every ready task
    once — instead of one getfin + one task step per loop turn.

    Semantics (what data lands where, FIFO disambiguation hand-off, parked
    retry on ID exhaustion, deadlock detection) match :class:`Scheduler`;
    only the interleaving — and therefore the Python-level driver overhead —
    differs. Works with either engine; `BatchedAsyncMemoryEngine.getfin_all`
    makes the drain itself a vectorized operation.
    """

    def _dispatch_fins(self, rids) -> None:
        """Bulk :meth:`_dispatch_fin`: same routing per ID, with the switch
        costs summed into one clock update (all IDs retire at the same epoch
        boundary, so incremental vs summed ticks reach the same time)."""
        pop_rid = self._rid_tok.pop
        waiting_pop = self._waiting_tok.pop
        wc = self._wait_count
        switches = 0
        for rid in rids:
            tok = pop_rid(rid)
            task = waiting_pop(tok, None)
            if task is None:
                self._unclaimed.add(tok)
                continue
            tid = id(task)
            cnt = wc.get(tid)
            if cnt is not None:
                if cnt > 1:
                    wc[tid] = cnt - 1
                    continue
                del wc[tid]
            switches += 1
            self._ready.append(task)
        if switches:
            self._tick_insts(self.cost.switch_insts * switches)
            self.t += self.cost.switch_stall_cycles * switches

    def run(self, tasks: Optional[Iterable[Task]] = None) -> dict:
        c = self.cost
        for task in tasks or ():
            self.spawn(task)
        while self._live > 0:
            if (self._waiting_tok or self._alloc_parked
                    or self.engine.outstanding or self.engine.finished_pending):
                self.engine.advance(self.t)
                rids = self.engine.getfin_all()
                # one poll per retrieved ID + the terminating empty poll
                self._tick_insts(c.getfin_insts * (len(rids) + 1))
                self._dispatch_fins(rids)
                # freed IDs: parked tasks can retry their issues. Stop as
                # soon as a retry parks again — the ID pool is exhausted and
                # every further retry this epoch would issue nothing.
                retries = min(len(rids), len(self._alloc_parked))
                for _ in range(retries):
                    ptask, pcmd = self._alloc_parked.popleft()
                    before = len(self._alloc_parked)
                    self._issue(ptask, pcmd)
                    if len(self._alloc_parked) > before:
                        break
            if self._ready:
                # step every currently-ready task once (snapshot: tasks that
                # re-queue themselves run again next epoch, after the poll)
                for _ in range(len(self._ready)):
                    task = self._ready.popleft()
                    self._run_task(task, self._results.pop(id(task), None))
            elif self._live > 0:
                self._idle_until_completion()
        return self.summary()


SCHEDULER_KINDS = {"scalar": Scheduler, "batched": BatchScheduler}
