"""Coroutine programming framework (§5.2) with a timed runtime.

Python generators stand in for the paper's C++20 coroutines. A task yields
*commands*; the scheduler implements Figure 4's runtime loop:

  1. a task yields :class:`Aload`/:class:`Astore` -> the engine issues the
     request (the instruction retires immediately), the task suspends on the
     returned ID;
  2. the event loop executes ``getfin`` to fetch a completed ID;
  3. the task waiting on that ID is resumed;
  4. the task reads/writes the returned bytes in SPM with synchronous
     :class:`SpmRead`/:class:`SpmWrite` (short, fixed latency — no misses).

Vector commands (:class:`AloadVec`/:class:`AstoreVec` + :class:`AwaitRids`)
issue a whole request vector per generator hop: the scheduler dispatches them
through the engine's ``aload_batch``/``astore_batch`` entry points (true
vector path on `BatchedAsyncMemoryEngine`, scalar-issue loop on the oracle)
and charges ONE amortized issue + ID-batch cost per vector — the §4.2
speculative ID pre-allocation applied at the framework layer. This is what
removes the per-request Python coroutine round-trip from the loop-parallel
workload ports.

:class:`Acquire`/:class:`Release` wrap the software memory-disambiguation set
(Listing 1): conflicting tasks suspend and are resumed in FIFO order when the
owner releases the block.

The scheduler keeps a cycle clock and instruction counter so AMU-mode
execution times / IPC / MLP come out of *actually running* the workloads
against the timed engine — this is what `benchmarks/fig8..fig10` drive.

Cost model (instructions per operation; 6-wide issue, 3 GHz — Table 2):
calibrated constants below; the DMA-mode ablation inflates the per-request
cost exactly where the paper says external engines pay it (descriptor setup,
doorbell, no speculative ID batching).
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Generator, Iterable, Optional

import numpy as np

from repro.core.disambiguation import CuckooAddressSet
from repro.core.engine import LOAD, STORE, AsyncEngineBase


# ---------------------------------------------------------------------- cost
@dataclass(frozen=True)
class CostModel:
    """Per-operation costs, calibrated against Table 4 (AMU ~= baseline at
    0.1 us <=> ~250 core cycles per awaited memory op: coroutine frame
    save/restore + scheduler bookkeeping + getfin loop + SPM (L2) latency)."""
    issue_width: int = 6
    ami_issue_insts: int = 8       # aload/astore + address generation + ID mv
    getfin_insts: int = 8          # poll + dispatch branch
    switch_insts: int = 40         # coroutine suspend+resume instructions
    switch_stall_cycles: float = 100.0  # dependent-chain stalls per switch
    spm_access_cycles: float = 15.0  # L2-latency SPM touch (Table 2)
    spm_byte_cycles: float = 0.25  # per-byte SPM streaming cost (reads the
                                   # DMA'd block out of L2 with dependent ops)
    refill_cycles: float = 20.0    # ALSU<->ASMC list round trip (batched)
    # software disambiguation (Listing 1): cuckoo probe + insert / remove +
    # waiter wakeup. Cache-resident hash tables -> tens of cycles.
    acquire_insts: int = 25
    acquire_stall_cycles: float = 5.0
    release_insts: int = 20
    release_stall_cycles: float = 3.0
    # DMA-mode extras (external-engine ablation: descriptor setup + MMIO
    # doorbell over the NoC, non-speculative issue)
    dma_descriptor_insts: int = 60
    dma_serialize_cycles: float = 180.0
    # vector AMI commands (AloadVec/AstoreVec): the paper's speculative ID
    # pre-allocation means a whole vector pays ONE issue + ID-batch cost
    # (ami_issue_insts, plus refill_cycles per actual list refill) and only a
    # small per-element marginal: address append into the request vector.
    vec_elem_insts: float = 1.5

    def insts_to_cycles(self, insts: float) -> float:
        return insts / self.issue_width


# ------------------------------------------------------------------ commands
@dataclass(frozen=True)
class Aload:
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class Astore:
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class AloadNoWait:
    """Issue an aload and continue executing (returns the request ID to the
    task immediately); pair with AwaitRid to suspend on completion later."""
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class AstoreNoWait:
    spm: int
    mem: int
    size: Optional[int] = None


@dataclass(frozen=True)
class AwaitRid:
    rid: int


@dataclass(frozen=True, eq=False)
class AloadVec:
    """Vectorized aload: issue ``len(spm)`` far->SPM requests as ONE AMI
    vector command (§4.2 metadata batching at the framework level). `spm` and
    `mem` are parallel sequences (lists/tuples/numpy arrays) of SPM offsets
    and far-memory addresses; `size` is the shared granularity (None -> the
    engine's configured granularity). With ``wait=False`` the task resumes
    immediately with a sequence of wait tokens — pair with
    :class:`AwaitRids` to suspend until the whole vector has completed.
    ``wait=True`` fuses the two: the task suspends on the whole vector in
    the same command (identical cost-model charges — AwaitRids itself is
    free and the coroutine switch is charged at completion dispatch — but
    one less host-side generator hop per batch)."""
    spm: object
    mem: object
    size: Optional[int] = None
    wait: bool = False


@dataclass(frozen=True, eq=False)
class AstoreVec:
    """Vectorized astore (SPM -> far memory); see :class:`AloadVec`."""
    spm: object
    mem: object
    size: Optional[int] = None
    wait: bool = False


@dataclass(frozen=True, eq=False)
class AwaitRids:
    """Suspend until EVERY token in `rids` has completed (one coroutine
    resume total — the amortized counterpart of N AwaitRid hops)."""
    rids: tuple


@dataclass(frozen=True)
class Acquire:     # software disambiguation: start_access
    addr: int


@dataclass(frozen=True)
class Release:     # software disambiguation: end_access
    addr: int


@dataclass(frozen=True, eq=False)
class AcquireVec:
    """Vectorized ``start_access`` (§5.1 applied to a pipeline batch):
    acquire EVERY block address in `addrs` in one generator hop — the
    counterpart of :class:`AloadVec` for the lock plane. `addrs` must be
    distinct and ascending (block-deduped total-order locking, see
    ``workloads._lock_set``): acquisition is sequential and on a conflict
    the task suspends in that block's FIFO, resuming acquisition from the
    next address when ownership is handed off. A K-chase batch therefore
    pays ONE coroutine round trip for its whole lock set instead of K
    per-op Acquire hops; the per-block cuckoo probe/insert work is charged
    per element AS each block is attempted — a vector suspended mid-set
    charges its remaining blocks at the hand-off continuation, not upfront
    at the hop (so disambiguation fractions stay comparable to Table 5)."""
    addrs: object


@dataclass(frozen=True, eq=False)
class ReleaseVec:
    """Vectorized ``end_access``: release every block in `addrs` (and hand
    each one's ownership to its head waiter) in one generator hop."""
    addrs: object


@dataclass(frozen=True, eq=False)
class SpmWrite:
    """Synchronous register->SPM store. `data` may be bytes or any
    C-contiguous ndarray (ports hand back computed arrays without a
    `.tobytes()` round trip; the cost model charges the same bytes)."""
    spm: int
    data: object


@dataclass(frozen=True)
class SpmRead:
    """Synchronous SPM->register load. The task receives a READ-ONLY numpy
    view aliasing live SPM (zero-copy): it observes later SpmWrites and DMA
    retirements into its range. Ports that need a snapshot across such an
    overwrite must `.copy()` (or double-buffer their slots); the scalar
    oracle engine asserts on reads racing in-flight loads."""
    spm: int
    size: int


def _nbytes(data) -> int:
    return data.nbytes if isinstance(data, np.ndarray) else len(data)


@dataclass(frozen=True)
class Cost:        # plain compute between memory ops
    insts: float = 0.0
    cycles: float = 0.0


@dataclass(frozen=True)
class WaitUntil:
    """Suspend until the core clock reaches `cycles` (an ABSOLUTE time).

    The open-loop arrival primitive: a serving port sleeps until a
    request's arrival time, then starts its gathers. If the clock is
    already past `cycles` the task continues immediately (the queueing
    delay is real — latency is measured from the scheduled arrival, not
    from the wake). Free of charge: the sleep models the task not
    existing yet, not the core doing work."""
    cycles: float


@dataclass(frozen=True)
class Now:
    """Resume immediately with the current core clock (cycles). Free of
    charge (a cycle-counter register read) — ports use it to timestamp
    request completions for per-request latency accounting."""
    pass


Task = Generator  # yields commands, receives command results


class DeadlockError(RuntimeError):
    pass


class Scheduler:
    def __init__(self, engine: AsyncEngineBase,
                 cost: CostModel = CostModel(),
                 disambiguator: Optional[CuckooAddressSet] = None,
                 dma_mode: bool = False,
                 retry=None):
        self.engine = engine
        self.cost = cost
        self.disamb = disambiguator
        self.dma_mode = dma_mode
        # ---- fault/recovery plane (§3.2 status + RetryPolicy) -------------
        # `retry` duck-types amu.config.RetryPolicy (max_retries/backoff).
        # All of this is dead weight on the zero-fault path: `_fault` is
        # False, every hook below is gated on it, and the run loops only
        # touch `_retry_heap` through truthiness checks on an empty list —
        # so fault-free traces/costs are bit-identical to pre-fault builds.
        self.retry = retry
        self._fault = bool(getattr(engine, "fault_enabled", False))
        self._rp_active = retry is not None and self._fault
        self._tok_req: Dict[int, list] = {}   # tok -> [kind,spm,mem,size,
        #                                        attempt, failover state 0/1/2]
        self._retry_heap: list = []           # (ready_cycles, seq, tok)
        self._retry_seq = 0
        self._tok_fstat: Dict[int, int] = {}  # tok -> final non-OK status
        self._group_toks: Dict[int, tuple] = {}  # id(task) -> awaited toks
        self.n_retries = 0
        self.n_failovers = 0
        self.n_failed = 0
        self.t = 0.0                       # core clock, cycles
        self.insts = 0.0                   # retired instructions
        self.disamb_cycles = 0.0           # time inside start/end_access
        self._ready: Deque[Task] = deque()
        self._alloc_parked: Deque[tuple] = deque()  # (task, command)
        self._results: Dict[int, object] = {}       # id(task) -> pending send
        # request IDs recycle after getfin, so the scheduler tracks each issue
        # with a unique token: rid -> token while in flight, and tasks wait
        # on tokens.
        self._tok = 0
        self._rid_tok: Dict[int, int] = {}
        self._waiting_tok: Dict[int, Task] = {}
        self._unclaimed: set = set()                # completed tokens, no waiter
        # vector-command state: tokens already issued for a parked vector
        # (id(task) -> list), and AwaitRids countdowns (id(task) -> remaining)
        self._vec_acc: Dict[int, list] = {}
        self._wait_count: Dict[int, int] = {}
        # AcquireVec continuations: id(task) -> (addrs, index suspended at)
        self._acq_state: Dict[int, tuple] = {}
        # wake planning (scalar oracle): token -> done time, a heap of
        # group-ready times (each waiting task resumes exactly when the
        # LAST of its tokens completes), and exact heap deletion via
        # dead-mark counts — a live group's wake may sit at or below the
        # clock when the finished backlog lags, so popping by `<= t` (the
        # BatchScheduler shortcut) would mistake it for dispatched here.
        self._tok_time: Dict[int, float] = {}
        self._wake_heap: list = []
        self._wake_dead: Dict[float, int] = {}
        self._wait_wake: Dict[int, float] = {}   # id(task) -> its group wake
        # open-loop sleepers: (wake_cycles, seq, task) heap; tasks suspended
        # on WaitUntil wake (FIFO within a tick via seq) once t >= wake
        self._sleeping: list = []
        self._sleep_seq = 0
        self._live = 0
        # AmuConfig(sanitize=True) shadow-state checker (sessions attach
        # it); None = every hook is skipped, bit-identical to pre-sanitizer
        self._san = None

    # --------------------------------------------------------------- helpers
    def _tick_insts(self, insts: float) -> None:
        self.insts += insts
        self.t += self.cost.insts_to_cycles(insts)

    def _sleep_until(self, task: Task, wake: float) -> None:
        """Park `task` until the clock reaches `wake` (WaitUntil). A wake
        at or below the clock requeues immediately — the arrival is in the
        past, the queueing delay is already being paid."""
        if wake <= self.t:
            self._ready.append(task)
        else:
            self._sleep_seq += 1
            heapq.heappush(self._sleeping, (wake, self._sleep_seq, task))

    def _wake_sleepers(self) -> None:
        """Move every sleeper whose wake time has arrived to the ready
        queue (in wake order, FIFO within a tick)."""
        while self._sleeping and self._sleeping[0][0] <= self.t:
            _, _, task = heapq.heappop(self._sleeping)
            self._ready.append(task)

    def _earliest_sleep(self) -> Optional[float]:
        """Earliest future event the runtime loop itself must service: a
        WaitUntil sleeper or a backoff-delayed retry slot. Both cap every
        clock jump/drain window the same way — the loop top requeues due
        sleepers (`_wake_sleepers`) and re-issues due retries
        (`_service_retries`) from exactly that instant."""
        s = self._sleeping[0][0] if self._sleeping else None
        if self._retry_heap:
            r = self._retry_heap[0][0]
            return r if s is None else min(s, r)
        return s

    # Token bookkeeping hooks — dict-based here (the oracle); BatchScheduler
    # overrides them with preallocated numpy maps for vectorized dispatch.
    def _new_token(self, rid: int) -> int:
        self._tok += 1
        self._rid_tok[rid] = self._tok
        self._tok_time[self._tok] = self.engine.done_time(rid)
        return self._tok

    def _new_tokens(self, rids) -> list:
        """Batch token mint for a successful vector issue (rids all != 0)."""
        return [self._new_token(int(rid)) for rid in rids]

    def _waiting_count(self) -> int:
        return len(self._waiting_tok)

    def _await_tokens(self, task: Task, toks) -> None:
        """Suspend `task` until every token in `toks` completes (tokens that
        already completed unclaimed are consumed immediately)."""
        if self._san is not None:
            self._san.on_await(toks)
        if self._fault:
            self._group_toks[id(task)] = tuple(int(t) for t in toks)
        remaining = 0
        wake = 0.0
        for tok in toks:
            if tok in self._unclaimed:
                self._unclaimed.discard(tok)
            else:
                self._waiting_tok[tok] = task
                wake = max(wake, self._tok_time[tok])
                remaining += 1
        if remaining:
            self._wait_count[id(task)] = remaining
            self._wait_wake[id(task)] = wake
            heapq.heappush(self._wake_heap, wake)
        else:
            if self._fault:
                self._deliver_status(task)
            self._ready.append(task)

    def _issue(self, task: Task, cmd) -> None:
        """Execute an Aload/Astore[-NoWait] or vector issue command."""
        if isinstance(cmd, (AloadVec, AstoreVec)):
            return self._issue_vec(task, cmd)
        c = self.cost
        self._tick_insts(c.ami_issue_insts)
        if self.dma_mode:
            self._tick_insts(c.dma_descriptor_insts)
            self.t += c.dma_serialize_cycles
        self.engine.advance(self.t)
        refills = self.engine.stats["free_refills"]
        if isinstance(cmd, (Aload, AloadNoWait)):
            rid = self.engine.aload(cmd.spm, cmd.mem, cmd.size)
        else:
            rid = self.engine.astore(cmd.spm, cmd.mem, cmd.size)
        if self.engine.stats["free_refills"] != refills:
            self.t += c.refill_cycles      # batched ID fetch round trip
        if rid == 0:
            self._alloc_parked.append((task, cmd))  # queue full: retry later
            return
        tok = self._new_token(rid)
        if self._rp_active:
            kind = LOAD if isinstance(cmd, (Aload, AloadNoWait)) else STORE
            self._tok_req[tok] = [kind, cmd.spm, cmd.mem, cmd.size, 0, 0]
        if isinstance(cmd, (AloadNoWait, AstoreNoWait)):
            self._results[id(task)] = tok        # token back, keep running
            self._ready.append(task)
        else:
            self._await_tokens(task, (tok,))

    def _issue_vec(self, task: Task, cmd) -> None:
        """Execute an AloadVec/AstoreVec for `task`: one amortized issue cost,
        one engine batch call. If the ID pool exhausts mid-vector, the
        remainder parks (retried as completions free IDs) and the task only
        resumes once every element has been issued."""
        c = self.cost
        n = len(cmd.spm)
        acc = self._vec_acc.pop(id(task), [])
        if n == 0:
            self._results[id(task)] = tuple(acc)
            self._ready.append(task)
            return
        # speculative ID pre-allocation: one issue + ID-batch cost per vector
        self._tick_insts(c.ami_issue_insts + c.vec_elem_insts * n)
        if self.dma_mode:
            # external engines pay descriptor setup + doorbell per request
            self._tick_insts(c.dma_descriptor_insts * n)
            self.t += c.dma_serialize_cycles * n
        self.engine.advance(self.t)
        refills = self.engine.stats["free_refills"]
        if isinstance(cmd, AloadVec):
            rids = self.engine.aload_batch(cmd.spm, cmd.mem, cmd.size)
        else:
            rids = self.engine.astore_batch(cmd.spm, cmd.mem, cmd.size)
        self.t += c.refill_cycles * (self.engine.stats["free_refills"] - refills)
        # allocation fails as a zero suffix: full when the last rid is live
        k = n if rids[n - 1] else int(np.count_nonzero(rids))
        toks = self._new_tokens(rids[:k]) if k else []
        if self._rp_active and k:
            self._record_vec_reqs(cmd, toks, k)
        if k < n:
            acc.extend(toks)
            rest = type(cmd)(cmd.spm[k:], cmd.mem[k:], cmd.size, cmd.wait)
            self._vec_acc[id(task)] = acc
            self._alloc_parked.append((task, rest))
            return
        if acc:                             # parked earlier: stitch the tail
            acc.extend(toks)
            toks = tuple(acc)
        if cmd.wait:                        # fused await: suspend in place
            self._await_tokens(task, toks)
        else:                               # tokens straight through (ndarray
            self._results[id(task)] = toks  # on the batch scheduler, list on
            self._ready.append(task)        # the oracle)

    def _record_vec_reqs(self, cmd, toks, k: int) -> None:
        """Retry-plane bookkeeping for a vector issue: remember each lane's
        (kind, spm, mem, size) so a failed lane can be re-issued verbatim.
        Fault-mode-only cost, charged nothing on the clock."""
        kind = LOAD if isinstance(cmd, AloadVec) else STORE
        spm, mem, size = cmd.spm, cmd.mem, cmd.size
        req = self._tok_req
        for i, tok in enumerate(toks):
            req[int(tok)] = [kind, int(spm[i]), int(mem[i]), size, 0, 0]

    def _run_task(self, task: Task, send_value=None) -> None:
        """Resume `task`, process the command it yields (if not finished)."""
        c = self.cost
        try:
            cmd = task.send(send_value)
        except StopIteration:
            self._live -= 1
            return
        if isinstance(cmd, (Aload, Astore, AloadNoWait, AstoreNoWait,
                            AloadVec, AstoreVec)):
            self._issue(task, cmd)
        elif isinstance(cmd, SpmRead):
            self.t += c.spm_access_cycles + c.spm_byte_cycles * cmd.size
            self._tick_insts(1 + cmd.size // 8)
            self._results[id(task)] = self.engine.spm_read(cmd.spm, cmd.size)
            self._ready.append(task)
        elif isinstance(cmd, Cost):
            self._tick_insts(cmd.insts)
            self.t += cmd.cycles
            self._ready.append(task)
        elif isinstance(cmd, WaitUntil):
            self._sleep_until(task, float(cmd.cycles))
        elif isinstance(cmd, Now):
            self._results[id(task)] = self.t
            self._ready.append(task)
        elif isinstance(cmd, AwaitRid):
            self._await_tokens(task, (cmd.rid,))  # cmd.rid is the issue token
        elif isinstance(cmd, AwaitRids):
            self._await_tokens(task, cmd.rids)
        elif isinstance(cmd, SpmWrite):
            nbytes = _nbytes(cmd.data)
            self.t += c.spm_access_cycles + c.spm_byte_cycles * nbytes
            self._tick_insts(1 + nbytes // 8)
            self.engine.spm_write(cmd.spm, cmd.data)
            self._ready.append(task)
        elif isinstance(cmd, Acquire):
            assert self.disamb is not None, "no disambiguator configured"
            if self._san is not None:
                self._san.on_acquire(id(task), (cmd.addr,))
            t0 = self.t
            self._tick_insts(c.acquire_insts)  # hash + probe (Listing 1 l.7)
            self.t += c.acquire_stall_cycles
            ok = self.disamb.start_access(cmd.addr, waiter=task)
            self.disamb_cycles += self.t - t0
            if ok:
                self._ready.append(task)
            # else: suspended; Release will requeue it
        elif isinstance(cmd, Release):
            assert self.disamb is not None
            if self._san is not None:
                self._san.on_release(id(task), (cmd.addr,))
            t0 = self.t
            self._tick_insts(c.release_insts)
            self.t += c.release_stall_cycles
            waiter = self.disamb.end_access(cmd.addr)
            self.disamb_cycles += self.t - t0
            if waiter is not None:
                self._grant(waiter)
            self._ready.append(task)
        elif isinstance(cmd, AcquireVec):
            assert self.disamb is not None, "no disambiguator configured"
            addrs = [int(a) for a in cmd.addrs]
            if self._san is not None:
                self._san.on_acquire(id(task), addrs, vec=True)
            # one hop for the whole lock set; the per-block cuckoo
            # probe/insert work is charged inside _acquire_from as each
            # block is actually attempted — the prefix up to a conflict
            # now, the remainder on the hand-off continuation — so
            # disambiguation fractions attribute the work to the moment
            # it happens (Table 5 comparability for vector ports)
            self._acquire_from(task, addrs, 0)
        elif isinstance(cmd, ReleaseVec):
            assert self.disamb is not None
            addrs = [int(a) for a in cmd.addrs]
            if self._san is not None:
                self._san.on_release(id(task), addrs)
            t0 = self.t
            self._tick_insts(c.release_insts * len(addrs))
            self.t += c.release_stall_cycles * len(addrs)
            self.disamb_cycles += self.t - t0
            for a in addrs:
                waiter = self.disamb.end_access(a)
                if waiter is not None:
                    self._grant(waiter)
            self._ready.append(task)
        else:
            raise TypeError(f"unknown command {cmd!r}")

    def _acquire_from(self, task: Task, addrs, i: int) -> None:
        """Acquire ``addrs[i:]`` in order for `task`, charging each block's
        cuckoo probe/insert as it is attempted (a failed probe is still a
        probe). On a conflict the task is already enqueued in that block's
        waiter FIFO; remember where it stopped so the Release hand-off can
        continue the acquisition — the remaining blocks' charges then land
        at continuation time, not upfront at the AcquireVec hop."""
        c = self.cost
        n = len(addrs)
        while i < n:
            t0 = self.t
            self._tick_insts(c.acquire_insts)
            self.t += c.acquire_stall_cycles
            self.disamb_cycles += self.t - t0
            if not self.disamb.start_access(addrs[i], waiter=task):
                self._acq_state[id(task)] = (addrs, i)
                return
            i += 1
        self._ready.append(task)

    def _grant(self, waiter: Task) -> None:
        """A Release handed `waiter` ownership of the released block: resume
        it — or, if it was suspended mid-:class:`AcquireVec`, continue
        acquiring its remaining addresses (the block it waited on is now
        owned via the hand-off)."""
        st = self._acq_state.pop(id(waiter), None)
        if st is None:
            self._ready.append(waiter)
        else:
            addrs, i = st
            self._acquire_from(waiter, addrs, i + 1)

    def _dispatch_fin(self, rid: int) -> None:
        """Route a completed request ID to its awaiting task (if any). A task
        suspended on AwaitRids only resumes — and only pays the coroutine
        switch once — when its LAST outstanding token completes.

        In fault mode the completion carries a status (`engine.fin_status`,
        set by the getfin that produced `rid`): a failed completion first
        consults the RetryPolicy — the token stays pending while its request
        is re-issued — and only a final (retry-exhausted, failover-failed)
        status reaches the awaiting task."""
        if self._fault:
            status = self.engine.fin_status
            if status:
                tok = self._rid_tok.pop(rid)
                if self._rp_active and self._schedule_retry(tok, status):
                    return               # re-issue pending: token stays live
                self._mark_failed(tok, status)
                self._tok_time.pop(tok, None)
                self._complete_token(tok)
                return
        tok = self._rid_tok.pop(rid)
        if self._rp_active:
            self._tok_req.pop(tok, None)
        self._tok_time.pop(tok, None)
        self._complete_token(tok)

    def _complete_token(self, tok: int) -> None:
        """Final-completion half of dispatch: group countdown, exact wake
        deletion, status delivery (fault mode) and the coroutine switch."""
        task = self._waiting_tok.pop(tok, None)
        if task is None:
            self._unclaimed.add(tok)
            return
        cnt = self._wait_count.get(id(task))
        if cnt is not None:
            if cnt > 1:
                self._wait_count[id(task)] = cnt - 1
                return                       # still waiting on more tokens
            del self._wait_count[id(task)]
        wake = self._wait_wake.pop(id(task), None)
        if wake is not None:                 # exact heap deletion (see init)
            self._wake_dead[wake] = self._wake_dead.get(wake, 0) + 1
        if self._fault:
            self._deliver_status(task)
        self._tick_insts(self.cost.switch_insts)  # resume the awaiter
        self.t += self.cost.switch_stall_cycles
        self._ready.append(task)

    # ------------------------------------------------- fault/recovery plane
    def _deliver_status(self, task: Task) -> None:
        """Hand the resuming task its per-lane statuses as the await's send
        value: an int for single-token awaits, an int8 array (lane-aligned)
        for vector awaits. 0/all-zero means every lane succeeded."""
        toks = self._group_toks.pop(id(task), None)
        if toks is None:
            return                       # not an await resume (issue/SPM/...)
        fst = self._tok_fstat
        if len(toks) == 1:
            self._results[id(task)] = fst.pop(toks[0], 0)
        else:
            self._results[id(task)] = np.array(
                [fst.pop(t, 0) for t in toks], np.int8)

    def _mark_failed(self, tok: int, status: int) -> None:
        """Record a token's FINAL failure status (delivered to its awaiter)."""
        self._tok_fstat[tok] = int(status)
        self.n_failed += 1
        if self._rp_active:
            self._tok_req.pop(tok, None)

    def _schedule_retry(self, tok: int, status: int) -> bool:
        """Decide recovery for a failed completion. Returns True when a
        re-issue (retry with exponential backoff, or a one-shot failover to
        the region's configured alternate) was scheduled — the token stays
        pending and its awaiting task keeps waiting. False means the failure
        is final."""
        req = self._tok_req.get(tok)
        if req is None:
            return False
        rp = self.retry
        if req[4] < rp.max_retries:
            delay = rp.backoff * (2.0 ** req[4])
            req[4] += 1
        elif req[5] == 0 and \
                self.engine.far.failover_index(req[2]) is not None:
            # retries exhausted on the home path: one failover attempt
            # through the region's configured alternate (same far-memory
            # address — an alternate path/replica, so the data plane is
            # unchanged; only the timing/fault draws route differently)
            delay = rp.backoff * (2.0 ** req[4])
            req[5] = 1
        else:
            return False
        self._retry_seq += 1
        heapq.heappush(self._retry_heap,
                       (self.t + delay, self._retry_seq, tok))
        return True

    def _rebind_token(self, tok: int, rid: int) -> None:
        """Point an existing (still-awaited) token at its re-issued rid."""
        self._rid_tok[rid] = tok
        self._tok_time[tok] = self.engine.done_time(rid)

    def _service_retries(self) -> None:
        """Re-issue every retry whose backoff slot has arrived (loop-top
        hook, the retry counterpart of `_wake_sleepers`). The re-issue pays
        the normal AMI issue cost and enters the far model like any other
        request — retry traffic is charged to the ledger honestly. If the
        ID pool is exhausted the slot is pushed back and re-attempted next
        turn (completions free IDs each turn)."""
        heap = self._retry_heap
        c = self.cost
        while heap and heap[0][0] <= self.t:
            _, _, tok = heapq.heappop(heap)
            req = self._tok_req[tok]
            kind, spm, mem, size = req[0], req[1], req[2], req[3]
            self._tick_insts(c.ami_issue_insts)
            self.engine.advance(self.t)
            far = self.engine.far
            refills = self.engine.stats["free_refills"]
            forced = req[5] == 1
            if forced:
                far._forced_region = far.failover_index(mem)
            try:
                if kind == LOAD:
                    rid = self.engine.aload(spm, mem, size)
                else:
                    rid = self.engine.astore(spm, mem, size)
            finally:
                if forced:
                    far._forced_region = None
            if self.engine.stats["free_refills"] != refills:
                self.t += c.refill_cycles  # batched ID fetch round trip
            if rid == 0:
                self._retry_seq += 1
                heapq.heappush(heap, (self.t, self._retry_seq, tok))
                return
            if forced:
                req[5] = 2
                self.n_failovers += 1
            else:
                self.n_retries += 1
            self._rebind_token(tok, rid)

    def _idle_until_completion(self) -> None:
        """Nothing runnable: validate liveness and advance to the next
        completion, with exact-wake planning (the BatchScheduler idea,
        scalar-loop-exact): any completion that retires strictly before the
        earliest group-ready time cannot resume a task, so its poll turn is
        replayed here in a tight loop — same per-turn accounting (advance to
        the completion, one getfin charge, dispatch) as the runtime loop,
        bit-for-bit — instead of paying a full loop turn per completion.
        Parked tasks can be unblocked by ANY completion (a freed ID), so
        they force single-stepping; the readying completion itself is left
        to the runtime loop, which polls it and runs the awakened task in
        the same turn, exactly as before. Sleepers (WaitUntil) cap every
        jump/drain window at their earliest wake: a waking sleeper issues
        new requests from that instant, so the clock must not overshoot
        it."""
        if not (self._waiting_count() or self._alloc_parked
                or self._sleeping or self._retry_heap):
            raise DeadlockError("live tasks but none ready/waiting")
        c = self.cost
        sleep0 = self._earliest_sleep()
        heap = self._wake_heap
        dead = self._wake_dead
        while heap and dead.get(heap[0]):  # exact lazy deletion
            if dead[heap[0]] == 1:
                del dead[heap[0]]
            else:
                dead[heap[0]] -= 1
            heapq.heappop(heap)
        # heap[0] (if any) is now a LIVE group's wake; when it already sits
        # at/below the clock its final token waits in the finished backlog,
        # so only a strictly-future wake opens the drain window
        if heap and not self._alloc_parked:
            wake = heap[0] if sleep0 is None else min(heap[0], sleep0)
            while wake > self.t:
                next_done = self.engine.next_completion_time
                # retirement happens at max(t, next_done): only provably
                # pre-wake turns (every retired token non-final) drain here
                if next_done is None or max(self.t, next_done) >= wake:
                    break
                self.t = max(self.t, next_done)
                self.engine.advance(self.t)
                self._tick_insts(c.getfin_insts)
                rid = self.engine.getfin()
                if rid:
                    self._dispatch_fin(rid)
        next_done = self.engine.next_completion_time
        if next_done is None:
            if self.engine.finished_pending:
                return                     # drain via getfin next round
            if sleep0 is not None:         # nothing in flight: jump to the
                self.t = max(self.t, sleep0)   # next arrival
                self.engine.advance(self.t)
                return
            raise DeadlockError(
                f"{self._waiting_count()} waiting, "
                f"{len(self._alloc_parked)} parked, none outstanding")
        if sleep0 is not None:
            next_done = min(next_done, sleep0)
        self.t = max(self.t, next_done)
        self.engine.advance(self.t)

    # ------------------------------------------------------------------ API
    def spawn(self, task: Task) -> None:
        self._live += 1
        self._ready.append(task)

    @property
    def live(self) -> int:
        """Spawned tasks that have not finished (the rack arbiter polls
        this to know when a core's port is done)."""
        return self._live

    def step(self) -> None:
        """One runtime-loop turn: wake sleepers, service retries, poll one
        completion, run one ready task (or idle to the next completion).
        :meth:`run` is exactly `while live: step()` — an external arbiter
        (``repro.core.rack``) interleaving `step()` calls across schedulers
        reproduces each scheduler's solo execution bit-for-bit."""
        c = self.cost
        if self._sleeping:             # arrivals whose time has come
            self._wake_sleepers()
        if self._retry_heap:           # backoff slots whose time has come
            self._service_retries()
        # event loop: poll completions first (Fig 4 step 3)
        if (self._waiting_count() or self._alloc_parked
                or self.engine.outstanding or self.engine.finished_pending):
            self.engine.advance(self.t)
            self._tick_insts(c.getfin_insts)
            rid = self.engine.getfin()
            if rid:
                self._dispatch_fin(rid)
                # freed an ID: a parked task can retry its issue
                if self._alloc_parked:
                    ptask, pcmd = self._alloc_parked.popleft()
                    self._issue(ptask, pcmd)
        if self._ready:
            task = self._ready.popleft()
            self._run_task(task, self._results.pop(id(task), None))
        elif self._live > 0:
            self._idle_until_completion()

    def run(self, tasks: Optional[Iterable[Task]] = None) -> dict:
        """Drive all tasks to completion; returns timing/throughput stats."""
        for task in tasks or ():
            self.spawn(task)
        while self._live > 0:
            self.step()
        return self.summary()

    def summary(self) -> dict:
        far = self.engine.far
        out = {
            "cycles": self.t,
            "insts": self.insts,
            "ipc": self.insts / max(self.t, 1e-9),
            "mlp": far.avg_mlp(self.t),
            "requests": far.requests,
            "bytes": far.bytes_moved,
            "disamb_cycles": self.disamb_cycles,
            "disamb_frac": self.disamb_cycles / max(self.t, 1e-9),
        }
        if self._fault:
            # logical requests = far-model entries minus recovery re-issues;
            # availability = fraction of logical requests that ultimately
            # succeeded (possibly after retries/failover)
            logical = far.requests - self.n_retries - self.n_failovers
            out["faults_injected"] = far.faults_injected
            out["retries"] = self.n_retries
            out["timeouts"] = far.timeouts
            out["failovers"] = self.n_failovers
            out["failed"] = self.n_failed
            out["availability"] = 1.0 - self.n_failed / max(logical, 1)
        return out

    def reset_stats(self) -> None:
        """Zero the recovery-plane counters and drop any in-flight retry
        state — the scheduler-side counterpart of
        :meth:`FarMemoryModel.reset_stats` for a prepare/measure split.
        Pending backoff slots are abandoned (their requests were warmup
        traffic); tokens already awaited stay resolvable via the engine."""
        self.n_retries = 0
        self.n_failovers = 0
        self.n_failed = 0
        self._retry_heap.clear()
        self._tok_req.clear()
        self._tok_fstat.clear()
        self._group_toks.clear()


class BatchScheduler(Scheduler):
    """Batch-stepped runtime loop (§4.2 metadata batching applied to the host
    model): each *epoch* drains ALL currently-finished IDs in one
    ``getfin_all`` sweep, resumes every awaiter, then steps every ready task
    once — instead of one getfin + one task step per loop turn.

    Semantics (what data lands where, FIFO disambiguation hand-off, parked
    retry on ID exhaustion, deadlock detection) match :class:`Scheduler`;
    only the interleaving — and therefore the Python-level driver overhead —
    differs. Works with either engine; `BatchedAsyncMemoryEngine.getfin_all`
    makes the drain itself a vectorized operation.

    Token routing is a numpy data plane rather than the oracle's dicts: a
    preallocated ``rid -> token`` array, growable ``token -> waiter-group``
    / ``token -> completed-unclaimed`` maps, and per-group outstanding
    counters. :meth:`_dispatch_fins` retires a whole getfin_all epoch in a
    handful of numpy ops (gather tokens, gather groups, scatter-subtract
    counters, find the groups that hit zero) instead of per-rid dict pops —
    the §4.2 metadata-batching idea applied to completion dispatch itself.
    """

    _GROW = 1024

    def __init__(self, engine: AsyncEngineBase,
                 cost: CostModel = CostModel(),
                 disambiguator: Optional[CuckooAddressSet] = None,
                 dma_mode: bool = False,
                 retry=None):
        super().__init__(engine, cost, disambiguator, dma_mode, retry)
        # rid -> token map (slot 0 unused; rids are 1-based)
        self._rid_tok = np.zeros(engine.config.queue_length + 1, np.int64)
        # token-indexed maps (slot 0 unused; tokens are 1-based)
        self._tok_group = np.full(self._GROW, -1, np.int64)
        self._tok_done = np.zeros(self._GROW, bool)
        self._tok_time = np.zeros(self._GROW, np.float64)
        # waiter groups: one per suspended task; counters hit 0 -> resume
        self._group_task: list = []
        self._group_left = np.zeros(self._GROW, np.int64)
        self._n_wait_groups = 0
        self._n_unclaimed = 0            # completed tokens nobody awaits yet
        # wake planning: each waiting group readies exactly when its LAST
        # token completes; the idle path jumps the clock straight there
        # instead of crawling one completion (= one empty epoch) at a time
        self._wake_heap: list = []

    # ------------------------------------------------- token plumbing hooks
    def _grow_tok_maps(self) -> None:
        grow = max(self._tok_group.size, self._tok + self._GROW)
        self._tok_group = np.concatenate(
            [self._tok_group, np.full(grow, -1, np.int64)])
        self._tok_done = np.concatenate(
            [self._tok_done, np.zeros(grow, bool)])
        self._tok_time = np.concatenate(
            [self._tok_time, np.zeros(grow, np.float64)])

    def _new_token(self, rid: int) -> int:
        self._tok += 1
        tok = self._tok
        if rid >= self._rid_tok.size:            # queue_length was resized up
            self._rid_tok = np.concatenate(
                [self._rid_tok, np.zeros(rid + 1 - self._rid_tok.size,
                                         np.int64)])
        self._rid_tok[rid] = tok
        if tok >= self._tok_group.size:
            self._grow_tok_maps()
        self._tok_group[tok] = -1
        self._tok_time[tok] = self.engine.done_time(rid)
        return tok

    def _new_tokens(self, rids) -> list:
        """Vectorized token mint: tokens are sequential, so a whole vector
        issue is a handful of fancy-index stores instead of a per-rid loop."""
        k = len(rids)
        toks = np.arange(self._tok + 1, self._tok + k + 1)
        self._tok += k
        if self._tok >= self._tok_group.size:
            self._grow_tok_maps()
        rids = np.asarray(rids, np.int64)
        if self._rid_tok.size <= self.engine.config.queue_length \
                and int(rids.max()) >= self._rid_tok.size:  # resized up
            self._rid_tok = np.concatenate(
                [self._rid_tok, np.zeros(int(rids.max()) + 1
                                         - self._rid_tok.size, np.int64)])
        self._rid_tok[rids] = toks
        self._tok_group[toks] = -1
        self._tok_time[toks] = self.engine.done_times(rids)
        return toks

    def _waiting_count(self) -> int:
        return self._n_wait_groups

    # Token maps grow with every token ever minted. At quiesce points — no
    # request in flight, no waiter, no unclaimed completion, nothing parked,
    # so no live token reference can exist — the maps recycle, keeping
    # resident memory bounded by the busiest in-flight window instead of
    # the total request count of a long sweep.
    _RECYCLE_AT = 1 << 16

    def _maybe_recycle_tokens(self) -> None:
        if (self._tok < self._RECYCLE_AT or self._n_wait_groups
                or self._n_unclaimed or self._alloc_parked
                or self._retry_heap or self.engine.active_requests):
            return
        self._tok = 0
        self._tok_group = np.full(self._GROW, -1, np.int64)
        self._tok_done = np.zeros(self._GROW, bool)
        self._tok_time = np.zeros(self._GROW, np.float64)
        self._group_task = []
        self._group_left = np.zeros(self._GROW, np.int64)
        self._wake_heap.clear()          # all entries are <= now: stale
        if self._fault:
            # token numbers restart: drop bookkeeping keyed by old tokens
            # (all final — no waiter/retry/unclaimed state exists here)
            self._tok_req.clear()
            self._tok_fstat.clear()
            self._group_toks.clear()
        if self._san is not None:
            self._san.on_token_recycle()

    def _idle_until_completion(self) -> None:
        """Idle step with wake planning: nothing is runnable, so no new
        issues can occur before some waiter resumes — it is therefore safe
        (and exact) to jump the clock to the earliest group-ready time (the
        max done-time of that group's tokens) instead of crawling one
        completion per epoch. With tasks parked on ID exhaustion, any single
        completion can unblock them, so fall back to single-stepping.
        Sleepers (WaitUntil) cap the jump at their earliest wake — a waking
        arrival issues new requests from that instant."""
        if not (self._n_wait_groups or self._alloc_parked or self._sleeping
                or self._retry_heap):
            raise DeadlockError("live tasks but none ready/waiting")
        sleep0 = self._earliest_sleep()
        next_done = self.engine.next_completion_time
        if next_done is None:
            if self.engine.finished_pending:
                return                     # drain via getfin next round
            if sleep0 is not None:         # nothing in flight: jump to the
                self.t = max(self.t, sleep0)   # next arrival
                self.engine.advance(self.t)
                return
            raise DeadlockError(
                f"{self._n_wait_groups} waiting, "
                f"{len(self._alloc_parked)} parked, none outstanding")
        heap = self._wake_heap
        while heap and heap[0] <= self.t:  # groups already dispatched
            heapq.heappop(heap)
        if self._alloc_parked or not heap:
            target = next_done
        else:
            target = heap[0]
        if sleep0 is not None:
            target = min(target, sleep0)
        self.t = max(self.t, target)
        self.engine.advance(self.t)

    def _new_group(self, task: Task, count: int, wake_time: float) -> int:
        """Register a waiter group: `task` resumes when `count` of its
        tokens complete, which wake planning knows happens at `wake_time`."""
        gid = len(self._group_task)
        self._group_task.append(task)
        if gid >= self._group_left.size:
            self._group_left = np.concatenate(
                [self._group_left,
                 np.zeros(max(self._group_left.size, self._GROW), np.int64)])
        self._group_left[gid] = count
        self._n_wait_groups += 1
        heapq.heappush(self._wake_heap, wake_time)
        return gid

    def _await_tokens(self, task: Task, toks) -> None:
        if self._san is not None:
            self._san.on_await(toks)
        if self._fault:
            self._group_toks[id(task)] = tuple(int(t) for t in toks)
        if len(toks) == 1:                       # AwaitRid / awaited scalar
            tok = toks[0]                        # issue: skip array overhead
            if self._tok_done[tok]:
                self._tok_done[tok] = False
                self._n_unclaimed -= 1
                if self._fault:
                    self._deliver_status(task)
                self._ready.append(task)
                return
            self._tok_group[tok] = self._new_group(
                task, 1, float(self._tok_time[tok]))
            return
        toks = np.asarray(toks, np.int64)
        if toks.size == 0:
            if self._fault:
                self._deliver_status(task)
            self._ready.append(task)
            return
        done = self._tok_done[toks]
        ds = int(done.sum())
        if ds == toks.size:
            self._tok_done[toks] = False         # consume unclaimed tokens
            self._n_unclaimed -= toks.size
            if self._fault:
                self._deliver_status(task)
            self._ready.append(task)
            return
        if ds:
            self._tok_done[toks[done]] = False
            self._n_unclaimed -= ds
            pending = toks[~done]
        else:
            pending = toks                       # common case: none done yet
        self._tok_group[pending] = self._new_group(
            task, pending.size, float(self._tok_time[pending].max()))

    def _dispatch_fins(self, rids) -> None:
        """Vectorized bulk dispatch: route a whole epoch of completed IDs to
        their waiter groups in O(few numpy ops). Tasks resume in the same
        order the oracle's per-rid loop would produce (a group becomes ready
        exactly where its LAST outstanding token sits in `rids`); the switch
        costs are summed into one clock update, as before."""
        if not rids:
            return
        if self._fault:
            sts = self.engine.fin_statuses
            if any(sts):
                # some completion failed: fall back to a per-rid ordered
                # loop (retry/failover scheduling + final-status routing).
                # Shared by BatchScheduler and EpochScheduler, so their
                # bit-identity survives fault injection.
                self._dispatch_fins_faulty(rids, sts)
                return
        if len(rids) <= 6:                       # sparse epoch: skip the
            n_ready = 0                          # vector machinery; groups
            for rid in rids:                     # still resume at their last
                tok = self._rid_tok[rid]         # token's position, and the
                gid = self._tok_group[tok]       # switch costs apply as one
                if gid < 0:                      # multiply, like the vector
                    self._tok_done[tok] = True   # path
                    self._n_unclaimed += 1
                    continue
                left = self._group_left[gid] - 1
                self._group_left[gid] = left
                if left == 0:
                    gtask = self._group_task[gid]
                    if self._fault:
                        self._deliver_status(gtask)
                    self._ready.append(gtask)
                    self._group_task[gid] = None
                    n_ready += 1
            if n_ready:
                self._n_wait_groups -= n_ready
                self._tick_insts(self.cost.switch_insts * n_ready)
                self.t += self.cost.switch_stall_cycles * n_ready
            return
        toks = self._rid_tok[np.asarray(rids, np.int64)]
        groups = self._tok_group[toks]
        unclaimed = groups < 0
        if unclaimed.any():
            self._tok_done[toks[unclaimed]] = True
            self._n_unclaimed += int(unclaimed.sum())
            if unclaimed.all():
                return
            groups = groups[~unclaimed]
        mx = int(groups.max()) + 1            # bincount beats subtract.at
        self._group_left[:mx] -= np.bincount(groups, minlength=mx)
        # groups hitting zero, ordered by their last occurrence in the epoch
        uniq, rev_idx = np.unique(groups[::-1], return_index=True)
        ready_mask = self._group_left[uniq] == 0
        n_ready = int(ready_mask.sum())
        if n_ready == 0:
            return
        last_pos = groups.size - 1 - rev_idx[ready_mask]
        for gid in uniq[ready_mask][np.argsort(last_pos, kind="stable")]:
            gtask = self._group_task[gid]
            if self._fault:
                self._deliver_status(gtask)
            self._ready.append(gtask)
            self._group_task[gid] = None
        self._n_wait_groups -= n_ready
        self._tick_insts(self.cost.switch_insts * n_ready)
        self.t += self.cost.switch_stall_cycles * n_ready

    def _dispatch_fins_faulty(self, rids, sts) -> None:
        """Ordered per-rid dispatch for an epoch containing failures: same
        group-countdown/unclaimed semantics as the ≤6-rid scalar path, plus
        retry/failover scheduling and final-status routing. Failed tokens
        whose re-issue is scheduled stay pending (their group does not
        count down)."""
        n_ready = 0
        rp = self._rp_active
        for rid, status in zip(rids, sts):
            tok = int(self._rid_tok[rid])
            if status:
                if rp and self._schedule_retry(tok, status):
                    continue             # token re-issued: group keeps waiting
                self._mark_failed(tok, status)
            elif rp:
                self._tok_req.pop(tok, None)
            gid = self._tok_group[tok]
            if gid < 0:
                self._tok_done[tok] = True
                self._n_unclaimed += 1
                continue
            left = self._group_left[gid] - 1
            self._group_left[gid] = left
            if left == 0:
                gtask = self._group_task[gid]
                self._deliver_status(gtask)
                self._ready.append(gtask)
                self._group_task[gid] = None
                n_ready += 1
        if n_ready:
            self._n_wait_groups -= n_ready
            self._tick_insts(self.cost.switch_insts * n_ready)
            self.t += self.cost.switch_stall_cycles * n_ready

    def _rebind_token(self, tok: int, rid: int) -> None:
        if rid >= self._rid_tok.size:    # queue_length was resized up
            self._rid_tok = np.concatenate(
                [self._rid_tok, np.zeros(rid + 1 - self._rid_tok.size,
                                         np.int64)])
        self._rid_tok[rid] = tok
        done = self.engine.done_time(rid)
        self._tok_time[tok] = done
        # wake planning: the re-issued completion is a lower bound on its
        # group's ready time — cap the idle jump there so the retried fin
        # is drained (and possibly re-retried) the turn it lands
        heapq.heappush(self._wake_heap, float(done))

    def step(self) -> None:
        """One batch-stepped epoch (the `run` loop body, arbiter-steppable)."""
        c = self.cost
        if self._sleeping:             # arrivals whose time has come
            self._wake_sleepers()
        if self._retry_heap:           # backoff slots whose time has come
            self._service_retries()
        if self._tok >= self._RECYCLE_AT:
            self._maybe_recycle_tokens()
        if (self._n_wait_groups or self._alloc_parked
                or self.engine.outstanding or self.engine.finished_pending):
            self.engine.advance(self.t)
            # poll only when the finished list can be non-empty — the
            # batch runtime KNOWS (it just advanced the clock), so
            # epochs between completions skip the drain entirely
            if self.engine.finished_pending:
                rids = self.engine.getfin_all()
                # one poll per retrieved ID + the terminating empty poll
                self._tick_insts(c.getfin_insts * (len(rids) + 1))
                self._dispatch_fins(rids)
                # freed IDs: parked tasks can retry their issues. The
                # retry budget is the engine's free-ID count, read once
                # per epoch: retries stop the moment a retry parks again
                # (pool drained mid-vector), so heavy ID exhaustion
                # costs O(retries), not O(parked^2) re-park churn.
                while self._alloc_parked and self.engine.free_ids:
                    ptask, pcmd = self._alloc_parked.popleft()
                    parked_before = len(self._alloc_parked)
                    self._issue(ptask, pcmd)
                    if len(self._alloc_parked) > parked_before:
                        break
        if self._ready:
            # step every currently-ready task once (snapshot: tasks that
            # re-queue themselves run again next epoch, after the poll)
            for _ in range(len(self._ready)):
                task = self._ready.popleft()
                self._run_task(task, self._results.pop(id(task), None))
        elif self._live > 0:
            self._idle_until_completion()


class EpochScheduler(BatchScheduler):
    """Epoch-fused runtime loop: ONE engine entry per scheduler epoch.

    The BatchScheduler already steps every ready task once per epoch, but
    each port's issue command still crosses the engine surface on its own —
    32 coroutines yielding AloadVec means 32 `aload_batch` calls (and 32
    far-model entries) per epoch. Here those calls only *stage*: the engine
    collects every staged batch into one SoA mega-batch and
    :meth:`~repro.core.engine.BatchedAsyncMemoryEngine.flush_epoch` enters
    the far model once, at the end of the epoch's step phase. The epoch-top
    drain likewise goes through one `getfin_epoch` call.

    What stays at staging time (it observes live state): ID allocation,
    SPM bounds checks, astore payload capture, and every cost-model charge
    (issue insts, DMA descriptors, refill round trips) — so the core clock
    `t` evolves identically to the per-command loop. What defers to the
    flush: the far-model math, AMART scatter, trace rows, token done-times
    (the epoch's tokens are a contiguous range, filled with one vector
    store) and waiter-group registration (replayed in command order).
    The flush ends by advancing the engine to the last staged time, which
    reproduces the cumulative retirement effect of the per-command loop's
    mid-epoch advances. The result is pinned bit-identical — trace,
    summary, stats, RNG bitstreams — to :class:`BatchScheduler` on the
    same engine (tests/test_epoch_fusion.py).

    On an engine without the epoch surface (the scalar oracle) every
    override falls through to the inherited per-command protocol.
    """

    def __init__(self, engine: AsyncEngineBase,
                 cost: CostModel = CostModel(),
                 disambiguator: Optional[CuckooAddressSet] = None,
                 dma_mode: bool = False,
                 retry=None):
        super().__init__(engine, cost, disambiguator, dma_mode, retry)
        self._fuse = bool(getattr(engine, "supports_epoch", False))
        # deferred per-epoch state: tokens minted since the last flush are
        # (_ep_tok_start, _tok]; their done-times land at the flush. Awaits
        # collected during the epoch replay in command order after that.
        self._ep_tok_start = self._tok
        self._ep_awaits: list = []

    # ------------------------------------------------- deferred token mint
    def _new_token(self, rid: int) -> int:
        # an immediate mint (scalar command, after its flush) carries its
        # real done-time already: keep it out of the epoch's deferred window
        # (_ep_tok_start, _tok], whose times are back-filled at the flush
        tok = super()._new_token(rid)
        self._ep_tok_start = self._tok
        return tok

    def _mint_deferred(self, rids) -> np.ndarray:
        """`_new_tokens` minus the done-time gather (filled at the flush)."""
        k = len(rids)
        toks = np.arange(self._tok + 1, self._tok + k + 1)
        self._tok += k
        if self._tok >= self._tok_group.size:
            self._grow_tok_maps()
        rids = np.asarray(rids, np.int64)
        if self._rid_tok.size <= self.engine.config.queue_length \
                and int(rids.max()) >= self._rid_tok.size:  # resized up
            self._rid_tok = np.concatenate(
                [self._rid_tok, np.zeros(int(rids.max()) + 1
                                         - self._rid_tok.size, np.int64)])
        self._rid_tok[rids] = toks
        self._tok_group[toks] = -1
        return toks

    def _maybe_recycle_tokens(self) -> None:
        super()._maybe_recycle_tokens()
        if self._tok == 0:                 # maps recycled (staging is empty
            self._ep_tok_start = 0         # at the loop top, so no live refs)

    def _service_retries(self) -> None:
        # retry re-issues take the immediate scalar engine path: flush any
        # staged epoch first so engine entry order = command order (a no-op
        # at the loop top, where retries are serviced)
        if self._fuse:
            self._flush_epoch()
        super()._service_retries()

    # ---------------------------------------------------- staged issue path
    def _issue(self, task: Task, cmd) -> None:
        if isinstance(cmd, (AloadVec, AstoreVec)):
            return self._issue_vec(task, cmd)
        # scalar commands take the immediate per-command path (staging a
        # 1-row numpy batch costs more host time than it saves); flushing
        # first keeps engine entry order = command order, so the trace and
        # far-model draw sequence stay identical to the per-command loop
        if self._fuse:
            self._flush_epoch()
        return super()._issue(task, cmd)

    def _issue_vec(self, task: Task, cmd) -> None:
        if not self._fuse:
            return super()._issue_vec(task, cmd)
        c = self.cost
        n = len(cmd.spm)
        acc = self._vec_acc.pop(id(task), [])
        if n == 0:
            self._results[id(task)] = tuple(acc)
            self._ready.append(task)
            return
        # speculative ID pre-allocation: one issue + ID-batch cost per vector
        self._tick_insts(c.ami_issue_insts + c.vec_elem_insts * n)
        if self.dma_mode:
            # external engines pay descriptor setup + doorbell per request
            self._tick_insts(c.dma_descriptor_insts * n)
            self.t += c.dma_serialize_cycles * n
        refills = self.engine.stats["free_refills"]
        kind = LOAD if isinstance(cmd, AloadVec) else STORE
        rids = self.engine.stage_epoch(kind, self.t, cmd.spm, cmd.mem,
                                       cmd.size)
        self.t += c.refill_cycles * (self.engine.stats["free_refills"]
                                     - refills)
        # allocation fails as a zero suffix: full when the last rid is live
        k = n if rids[n - 1] else int(np.count_nonzero(rids))
        toks = self._mint_deferred(rids[:k]) if k else []
        if self._rp_active and k:
            self._record_vec_reqs(cmd, toks, k)
        if k < n:
            acc.extend(toks)
            rest = type(cmd)(cmd.spm[k:], cmd.mem[k:], cmd.size, cmd.wait)
            self._vec_acc[id(task)] = acc
            self._alloc_parked.append((task, rest))
            return
        if acc:                             # parked earlier: stitch the tail
            acc.extend(toks)
            toks = tuple(acc)
        if cmd.wait:                        # fused await: suspend at flush
            self._ep_awaits.append((task, toks))
        else:
            self._results[id(task)] = toks
            self._ready.append(task)

    def _flush_epoch(self) -> None:
        """End the epoch: one engine/far entry for everything staged, fill
        the epoch's token done-times with one vector store, then register
        the deferred waiter groups in command order."""
        if not self.engine.epoch_staged and not self._ep_awaits:
            return                          # clean epoch: flush is a no-op
        tok_lo = self._ep_tok_start
        dones = self.engine.flush_epoch()
        if dones.size:
            self._tok_time[tok_lo + 1:tok_lo + 1 + dones.size] = dones
        self._ep_tok_start = self._tok
        if self._ep_awaits:
            awaits, self._ep_awaits = self._ep_awaits, []
            for task, toks in awaits:
                self._await_tokens(task, toks)

    # -------------------------------------------------------- runtime loop
    def step(self) -> None:
        if not self._fuse:
            return super().step()
        c = self.cost
        if self._sleeping:             # arrivals whose time has come
            self._wake_sleepers()
        if self._retry_heap:           # backoff slots whose time has come
            self._service_retries()
        if self._tok >= self._RECYCLE_AT:
            self._maybe_recycle_tokens()
        if (self._n_wait_groups or self._alloc_parked
                or self.engine.outstanding or self.engine.finished_pending):
            # one advance + (iff anything finished) one drain per epoch
            rids = self.engine.getfin_epoch(self.t)
            if rids is not None:
                self._tick_insts(c.getfin_insts * (len(rids) + 1))
                self._dispatch_fins(rids)
                # freed IDs: parked tasks can retry (staged, not issued)
                while self._alloc_parked and self.engine.free_ids:
                    ptask, pcmd = self._alloc_parked.popleft()
                    parked_before = len(self._alloc_parked)
                    self._issue(ptask, pcmd)
                    if len(self._alloc_parked) > parked_before:
                        break
        if self._ready:
            # step every currently-ready task once (snapshot: tasks that
            # re-queue themselves run again next epoch, after the poll)
            for _ in range(len(self._ready)):
                task = self._ready.popleft()
                self._run_task(task, self._results.pop(id(task), None))
            self._flush_epoch()
        elif self._live > 0:
            # a parked retry may have staged a partial vector with no
            # task left ready: flush it before idling on completions
            self._flush_epoch()
            self._idle_until_completion()


SCHEDULER_KINDS = {"scalar": Scheduler, "batched": BatchScheduler,
                   "fused": EpochScheduler}
