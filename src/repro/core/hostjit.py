"""Optional host-side JIT kernels for the fused epoch step.

The epoch-fused command plane (``EpochScheduler`` ->
``BatchedAsyncMemoryEngine.flush_epoch`` -> ``FarMemoryModel.issue_epoch``)
bottoms out in two scalar-sequential recurrences that numpy cannot fuse
across segment boundaries without changing float association:

* the per-link injection chain ``inject_i = max(now_i, free[link_i]);
  free[link_i] = inject_i + serial_i`` (link serialization across an
  arbitrary interleaving of segments and links), and
* the MLP ledger's issue-time accumulation, which must stay a sequential
  left-to-right float sum to remain bit-identical to n scalar ``record()``
  calls.

Both are pure float loops, so they JIT well. When :mod:`numba` is
importable and the ``AmuConfig.host_jit`` knob is on, the loops run as
``@njit`` kernels; otherwise the callers fall back to the pure-numpy
per-(segment x link) ``np.cumsum`` chunks / Python accumulation loop.
Every operation is a sequential IEEE binary add or max in the same order,
so the JIT and fallback paths are bit-identical — pinned by
tests/test_epoch_fusion.py.

numba is an *optional* dev dependency (see requirements-dev.txt); this
module must import cleanly without it.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

_chain_fn: Optional[Callable] = None
_seq_sum_fn: Optional[Callable] = None
_probed = False


def _probe() -> None:
    """Compile the kernels once, lazily, iff numba is importable."""
    global _chain_fn, _seq_sum_fn, _probed
    if _probed:
        return
    _probed = True
    try:
        from numba import njit
    except ImportError:
        return

    @njit(cache=True)
    def _chain(nows, serial, links, free, out):  # pragma: no cover - jitted
        for i in range(nows.size):
            f = free[links[i]]
            inj = nows[i] if nows[i] > f else f   # == max(now, free)
            out[i] = inj
            free[links[i]] = inj + serial[i]

    @njit(cache=True)
    def _seq_sum(values, init):                   # pragma: no cover - jitted
        acc = init
        for i in range(values.size):
            acc = acc + values[i]
        return acc

    # warm the dispatcher so first use inside a timed sweep isn't a compile
    _chain(np.zeros(1), np.zeros(1), np.zeros(1, np.int64), np.zeros(1),
           np.zeros(1))
    _seq_sum(np.zeros(1), 0.0)
    _chain_fn = _chain
    _seq_sum_fn = _seq_sum


def numba_available() -> bool:
    _probe()
    return _chain_fn is not None


def get_chain(enabled: bool) -> Optional[Callable]:
    """The jitted injection-chain kernel, or None (use the numpy path).

    Signature: ``chain(nows, serial, links, free, out)`` with ``nows``,
    ``serial``, ``out`` float64[n], ``links`` int64[n] (link index per row)
    and ``free`` float64[n_links] updated in place. Bit-identical to the
    scalar loop ``inj = max(now_i, free[l]); free[l] = inj + serial_i``.
    """
    if not enabled:
        return None
    _probe()
    return _chain_fn


def get_seq_sum(enabled: bool) -> Optional[Callable]:
    """The jitted sequential float accumulator, or None (Python loop).

    ``seq_sum(values, init) -> float`` performs ``init + v0 + v1 + ...``
    as strictly sequential binary adds — the ledger's bit-identity
    contract with n scalar ``record()`` calls.
    """
    if not enabled:
        return None
    _probe()
    return _seq_sum_fn
