"""The paper's 11 memory-bound benchmarks (Table 3), ported to AMI.

Each workload provides:

* ``build()`` -> a :class:`WorkloadInstance` with real numpy-backed far
  memory, coroutine tasks following the paper's porting paradigm (§5.2:
  loop-level parallelism for GUPS/HJ/HPCG/IS/STREAM, request-level
  parallelism for BS/HT/LL/SL/Redis, frontier parallelism for BFS), and a
  ``verify()`` that checks the far-memory contents / collected results
  against a serial numpy oracle.
* an :class:`IterationProfile` describing one logical work unit for the
  baseline out-of-order window model (64-byte line granularity, dependence
  structure, compute instruction count), declared on the builder's
  ``@workload`` registration.

Every builder registers itself into :data:`repro.amu.REGISTRY` via the
``@workload`` decorator (capabilities: vector/pipelined/locked/distinct/
frontier); port bodies yield commands through the typed facade
:data:`repro.amu.ctx` rather than constructing command objects by hand.

Sizes are scaled down from the paper (as the paper itself scales down for
simulation time) but keep the structural character: random vs sequential,
chase depth, granularity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional

import numpy as np

from repro.amu.commands import ctx
from repro.amu.registry import workload as _workload
from repro.configs.base import EngineConfig
from repro.core.engine import AMART_ENTRY_BYTES

LINE = 64  # baseline cache-line granularity

# Every workload has a vector (AloadVec/AstoreVec) port behind a
# `vector=True` builder knob; the scalar ports stay the default (and the
# differential oracle — tests pin vector execution to the scalar port's
# results). Loop-level-parallel benchmarks batch independent requests per
# generator hop (§5.2); the request-level-parallel chase workloads (HJ, HT,
# LL, SL, Redis) use software-pipelined ports instead: K concurrent chases
# per coroutine advance in lockstep, one AloadVec per round over the live
# set (the BS probe-batch pattern generalized — arXiv 2112.13306's software
# pipelining); BFS batches the per-chunk parent fetch/claim. Which port a
# workload carries is declared on its @workload registration (the `vector`/
# `pipelined` capabilities in repro.amu.REGISTRY).

# Zero-copy port idiom: SpmRead yields a read-only view aliasing live SPM.
# Ports do view arithmetic directly (`data.view(dt)`), hand computed arrays
# to SpmWrite without `.tobytes()`, and only copy (or double-buffer slots)
# where a value must survive a later DMA/SpmWrite into the same range — see
# the SL port (double-buffered node slots) and the pipelined SL port
# (per-chase node snapshots).


def _fit_spm(data_bytes: int, queue_length: int,
             floor: int = 64 * 1024) -> int:
    """Smallest power-of-two SPM that fits `data_bytes` of slots plus the
    AMART/queue metadata area (vector ports with big per-coroutine windows
    outgrow the default 64 KiB — the paper's SPM is an L2-slice, MiB-scale)."""
    need = data_bytes + queue_length * AMART_ENTRY_BYTES + 1
    spm = floor
    while spm < need:
        spm *= 2
    return spm


def _unique_keys(rng, n: int, lo: int = 1, hi: int = 1 << 40) -> "np.ndarray":
    """n distinct uint64 keys in [lo, hi) without materializing the range."""
    out = np.unique(rng.integers(lo, hi, size=2 * n + 16, dtype=np.uint64))
    while out.size < n:  # astronomically unlikely for our sizes
        more = rng.integers(lo, hi, size=2 * n, dtype=np.uint64)
        out = np.unique(np.concatenate([out, more]))
    return rng.permutation(out)[:n]


@dataclass(frozen=True)
class IterationProfile:
    """One logical work unit as the baseline OoO core sees it.

    `mlp_cap` and `local_cycles` are the two calibration knobs fitted against
    the paper's Table 4 / Fig 2 curves: `mlp_cap` is the *effective* sustained
    far-memory concurrency the Gem5 baseline achieves for this access pattern
    (second-order limits: TLB walks holding MSHRs, LSQ walks, line-fill
    serialization — well below the nominal 48 MSHRs for fine random RMW), and
    `local_cycles` is serialized per-iteration core/local-memory work (hash,
    page walk, loop control) that does not scale with far latency."""
    insts: float              # non-memory instructions
    chase: float = 0          # serially dependent far loads (pointer chase)
    indep_loads: float = 0    # independent far loads (64B lines)
    stores: float = 0         # far stores (issue after loads/compute)
    local_frac: float = 0.0   # fraction of far loads that hit local cache
    sequential: bool = False  # stride pattern (hardware prefetcher works)
    mlp_cap: float = 0.0      # 0 -> window-derived; else sustained-MLP cap
    local_cycles: float = 0.0 # serialized non-far cycles per iteration


@dataclass
class WorkloadInstance:
    name: str
    mem: np.ndarray                       # far-memory backing (uint8)
    tasks: List                           # generator tasks
    units: int                            # logical work units (for rates)
    engine_config: EngineConfig
    verify: Callable[[np.ndarray], bool]
    disambiguation: bool = False
    vector: bool = False                  # which port was built (stats label)
    # request-level ports (serving) fill one completion latency per logical
    # request during the run; the session turns it into RunStats req_* fields
    request_latency_cycles: Optional[np.ndarray] = None


def _cfg(granularity: int, queue_length: int = 256,
         spm_bytes: int = 64 * 1024, batch_ids: int = 31) -> EngineConfig:
    return EngineConfig(queue_length=queue_length, granularity=granularity,
                        spm_bytes=spm_bytes, batch_ids=batch_ids)


def _vec_cfg(granularity: int, coroutines: int, pipeline_k: int,
             data_bytes: int = 0) -> EngineConfig:
    """Engine config for a pipelined/vector port: ID pool sized to 2x the
    peak in-flight demand (vectors that park at exact occupancy burn their
    speedup on retry churn), SPM auto-fit when the slot windows outgrow the
    default 64 KiB."""
    qlen = min(2048, max(256, 2 * coroutines * pipeline_k))
    spm = _fit_spm(data_bytes, qlen) if data_bytes else 64 * 1024
    return _cfg(granularity, queue_length=qlen, spm_bytes=spm)


# =========================================================================
# GUPS — HPCC RandomAccess: read-modify-write random 8B words (LLP)
# =========================================================================
@_workload("GUPS", profile=IterationProfile(insts=8, indep_loads=1, stores=1,
                                            mlp_cap=6, local_cycles=165),
           vector=True, distinct=True,
           description="HPCC RandomAccess, 8B RMW updates")
def build_gups(seed: int = 0, table_words: int = 8192, updates: int = 4096,
               coroutines: int = 256, vector: bool = False,
               vec_chunk: int = 32, distinct: bool = False) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    table = rng.integers(0, 1 << 63, size=table_words, dtype=np.uint64)
    mem = table.view(np.uint8).copy()
    if distinct:
        # conflict-free update set (each slot touched at most once): makes
        # the final bytes schedule-independent for differential tests
        if updates > table_words:
            raise ValueError(f"distinct=True needs updates <= table_words "
                             f"({updates} > {table_words})")
        idx = rng.permutation(table_words)[:updates]
    else:
        idx = rng.integers(0, table_words, size=updates)
    vals = rng.integers(0, 1 << 63, size=updates, dtype=np.uint64)

    def task(c: int, lo: int, hi: int):
        spm = c * 8
        for k in range(lo, hi):
            addr = int(idx[k]) * 8
            yield ctx.aload(spm, addr, 8)
            data = yield ctx.spm_read(spm, 8)
            new = data.view(np.uint64) ^ vals[k]
            yield ctx.spm_write(spm, new)
            yield ctx.astore(spm, addr, 8)
            yield ctx.cost(insts=6)

    def vtask(c: int, lo: int, hi: int):
        base = c * vec_chunk * 8           # vec_chunk 8B slots per coroutine
        for k0 in range(lo, hi, vec_chunk):
            cnt = min(vec_chunk, hi - k0)
            addrs = idx[k0:k0 + cnt] * 8
            slots = base + np.arange(cnt) * 8
            yield ctx.aload_vec(slots, addrs, 8, wait=True)
            data = yield ctx.spm_read(base, cnt * 8)
            new = data.view(np.uint64) ^ vals[k0:k0 + cnt]
            yield ctx.spm_write(base, new)
            yield ctx.astore_vec(slots, addrs, 8, wait=True)
            yield ctx.cost(insts=6 * cnt)

    if vector:
        coroutines = min(coroutines, 32)
    bounds = np.linspace(0, updates, coroutines + 1).astype(int)
    mk = vtask if vector else task
    tasks = [mk(c, bounds[c], bounds[c + 1]) for c in range(coroutines)]

    expect = table.copy()
    for k in range(updates):
        expect[idx[k]] ^= vals[k]
    conflict_free = np.bincount(idx, minlength=table_words) <= 1

    def verify(mem_out: np.ndarray) -> bool:
        got = mem_out[:table_words * 8].view(np.uint64)
        # HPCC allows racy updates to diverge; conflict-free slots must match
        return bool(np.array_equal(got[conflict_free], expect[conflict_free]))

    # vector mode wants every coroutine's whole chunk in flight: size the ID
    # queue to the aggregate vector demand (parking stays correct but slow)
    cfg = _cfg(8, queue_length=min(2048, max(256, coroutines * vec_chunk))) \
        if vector else _cfg(8)
    return WorkloadInstance("GUPS", mem, tasks, updates, cfg, verify,
                            vector=vector)


# =========================================================================
# STREAM — triad a = b + s*c with large-granularity (512B) aload/astore (LLP)
# =========================================================================
@_workload("STREAM", profile=IterationProfile(insts=160, indep_loads=16,
                                              stores=8, sequential=True,
                                              mlp_cap=64, local_cycles=226),
           vector=True, llvm_defaults={"block_doubles": 1},
           description="triad over 512B blocks (64 doubles/unit)")
def build_stream(seed: int = 0, n: int = 65536, block_doubles: int = 64,
                 coroutines: int = 32, vector: bool = False,
                 vec_chunk: int = 4) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    b = rng.standard_normal(n)
    c = rng.standard_normal(n)
    a = np.zeros(n)
    s = 3.0
    mem = np.concatenate([a, b, c]).view(np.uint8).copy()
    a_off, b_off, c_off = 0, n * 8, 2 * n * 8
    gran = block_doubles * 8
    blocks = n // block_doubles

    def task(coro: int, lo: int, hi: int):
        sb = coro * 2 * gran          # two input slots per coroutine
        for blk in range(lo, hi):
            off = blk * gran
            rb = yield ctx.aload(sb, b_off + off, gran, wait=False)
            rc = yield ctx.aload(sb + gran, c_off + off, gran, wait=False)
            yield ctx.await_rid(rb)
            yield ctx.await_rid(rc)
            db = yield ctx.spm_read(sb, gran)
            dc = yield ctx.spm_read(sb + gran, gran)
            out = db.view(np.float64) + s * dc.view(np.float64)
            yield ctx.cost(insts=2 * block_doubles)
            yield ctx.spm_write(sb, out)
            yield ctx.astore(sb, a_off + off, gran)

    def vtask(coro: int, lo: int, hi: int):
        # vec_chunk b-slots then vec_chunk c-slots, contiguous per coroutine
        sb = coro * 2 * vec_chunk * gran
        sc = sb + vec_chunk * gran
        for b0 in range(lo, hi, vec_chunk):
            cnt = min(vec_chunk, hi - b0)
            offs = np.arange(b0, b0 + cnt) * gran
            bslots = sb + np.arange(cnt) * gran
            cslots = sc + np.arange(cnt) * gran
            yield ctx.aload_vec(np.concatenate([bslots, cslots]),
                                np.concatenate([b_off + offs, c_off + offs]),
                                gran, wait=True)
            db = yield ctx.spm_read(sb, cnt * gran)
            dc = yield ctx.spm_read(sc, cnt * gran)
            out = db.view(np.float64) + s * dc.view(np.float64)
            yield ctx.cost(insts=2 * block_doubles * cnt)
            yield ctx.spm_write(sb, out)
            yield ctx.astore_vec(bslots, a_off + offs, gran, wait=True)

    if vector:
        coroutines = min(coroutines, 8)
    bounds = np.linspace(0, blocks, coroutines + 1).astype(int)
    mk = vtask if vector else task
    tasks = [mk(i, bounds[i], bounds[i + 1]) for i in range(coroutines)]
    expect = b + s * c

    def verify(mem_out: np.ndarray) -> bool:
        got = mem_out[a_off:a_off + n * 8].view(np.float64)
        return bool(np.allclose(got, expect))

    cfg = _cfg(gran)
    if vector:           # big per-coroutine windows outgrow the default SPM
        # ID pool sized to 2x the peak vector demand (2 loads + 1 store per
        # block in flight) so refills never park at exact occupancy
        qlen = min(2048, max(256, 6 * coroutines * vec_chunk))
        cfg = _cfg(gran, queue_length=qlen,
                   spm_bytes=_fit_spm(coroutines * 2 * vec_chunk * gran,
                                      qlen))
    return WorkloadInstance("STREAM", mem, tasks, blocks, cfg, verify,
                            vector=vector)


# =========================================================================
# BS — binary search over sorted 16B elements (RLP, dependent chase)
# =========================================================================
@_workload("BS", profile=IterationProfile(insts=120, chase=14,
                                          local_frac=0.5, local_cycles=60),
           vector=True,
           description="binary search, 16B elements, 14-deep chase")
def build_bs(seed: int = 0, n_elems: int = 16384, searches: int = 512,
             coroutines: int = 256, vector: bool = False) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    keys = np.sort(_unique_keys(rng, n_elems))
    payload = rng.integers(0, 1 << 63, size=n_elems, dtype=np.uint64)
    elems = np.empty(n_elems * 2, np.uint64)
    elems[0::2], elems[1::2] = keys, payload
    mem = elems.view(np.uint8).copy()
    queries = keys[rng.integers(0, n_elems, size=searches)]
    found_payload = np.zeros(searches, np.uint64)

    def task(c: int, qs: List[int]):
        spm = c * 16
        for qi in qs:
            target = queries[qi]
            lo, hi = 0, n_elems - 1
            while lo <= hi:
                mid = (lo + hi) // 2
                yield ctx.aload(spm, mid * 16, 16)
                data = yield ctx.spm_read(spm, 16)
                k, v = data.view(np.uint64)
                yield ctx.cost(insts=8)
                if k == target:
                    found_payload[qi] = v
                    break
                lo, hi = (mid + 1, hi) if k < target else (lo, mid - 1)

    def vtask(c: int, qs: "np.ndarray"):
        # probe batch: all of this task's searches advance in lock-step —
        # one AloadVec fetches the current mid element of every live search
        nq = len(qs)
        base = c * nq * 16                 # one 16B element slot per search
        lo = np.zeros(nq, np.int64)
        hi = np.full(nq, n_elems - 1, np.int64)
        live = np.ones(nq, bool)
        while live.any():
            act = np.nonzero(live)[0]
            mid = (lo[act] + hi[act]) // 2
            yield ctx.aload_vec(base + act * 16, mid * 16, 16, wait=True)
            yield ctx.cost(insts=8 * len(act))
            for pos, ai in enumerate(act):
                data = yield ctx.spm_read(int(base + ai * 16), 16)
                k, v = data.view(np.uint64)
                target = queries[qs[ai]]
                if k == target:
                    found_payload[qs[ai]] = v
                    live[ai] = False
                elif k < target:
                    lo[ai] = mid[pos] + 1
                else:
                    hi[ai] = mid[pos] - 1
                if live[ai] and lo[ai] > hi[ai]:
                    live[ai] = False

    if vector:
        coroutines = min(coroutines, 32)   # fewer tasks, each a probe batch
    qsplit = np.array_split(np.arange(searches), coroutines)
    if vector:
        tasks = [vtask(c, qs) for c, qs in enumerate(qsplit) if len(qs)]
    else:
        tasks = [task(c, list(qs)) for c, qs in enumerate(qsplit) if len(qs)]
    expect = payload[np.searchsorted(keys, queries)]

    def verify(mem_out: np.ndarray) -> bool:
        return bool(np.array_equal(found_payload, expect))

    cfg = _cfg(16, queue_length=min(1024, max(256, searches))) if vector \
        else _cfg(16)
    return WorkloadInstance("BS", mem, tasks, searches, cfg, verify,
                            vector=vector)


# =========================================================================
# Chained hash structures — shared helper (HJ probe, HT, Redis)
# node layout: [key u64 | value u64 | next i64 (byte offset, -1 end) | pad]
# =========================================================================
_NODE = 32


def _build_chains(rng, n_keys: int, n_buckets: int):
    keys = _unique_keys(rng, n_keys)
    vals = rng.integers(1, 1 << 62, size=n_keys, dtype=np.uint64)
    bucket_of = keys % n_buckets
    heads = np.full(n_buckets, -1, np.int64)
    nodes = np.zeros(n_keys * 4, np.uint64)  # key, val, next, pad per node
    for i in range(n_keys):
        b = bucket_of[i]
        nodes[4 * i + 0] = keys[i]
        nodes[4 * i + 1] = vals[i]
        nodes[4 * i + 2] = np.uint64(heads[b] if heads[b] >= 0
                                     else 0xFFFFFFFFFFFFFFFF)
        heads[b] = i * _NODE
    return keys.astype(np.uint64), vals, heads, nodes


_NIL64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _chase_chain(spm: int, head_off: int, target: int):
    """Generator fragment: follow a chain until key==target.
    Yields AMI commands; returns (node_off, value) via StopIteration value."""
    off = head_off
    while off != -1:
        yield ctx.aload(spm, off, _NODE)
        data = yield ctx.spm_read(spm, _NODE)
        k, v, nxt, _ = data.view(np.uint64)
        yield ctx.cost(insts=8)
        if k == target:
            return off, int(v)
        off = -1 if nxt == _NIL64 else int(nxt)
    return -1, 0


def _chase_chain_vec(base: int, heads, targets):
    """Software-pipelined counterpart of :func:`_chase_chain`: K chases
    advance in lockstep, one ``AloadVec`` per round over the still-live set
    (the BS probe-batch pattern generalized to chained structures). Chase i
    lands in SPM slot ``base + i*_NODE``; one zero-copy SpmRead view over the
    whole slot window serves every chase's node each round. Per-chase far
    traffic is identical to the scalar chase. Returns ``(offs, vals)`` int64/
    uint64 arrays via StopIteration (off -1 where the key was absent)."""
    targets = np.asarray(targets, np.uint64)
    nb = targets.size
    cur = np.asarray(heads, np.int64).copy()
    offs = np.full(nb, -1, np.int64)
    vals = np.zeros(nb, np.uint64)
    live = cur >= 0
    while live.any():
        act = np.nonzero(live)[0]
        yield ctx.aload_vec(base + act * _NODE, cur[act], _NODE, wait=True)
        data = yield ctx.spm_read(base, nb * _NODE)
        nodes = data.view(np.uint64).reshape(nb, 4)
        yield ctx.cost(insts=8 * act.size)
        k, v, nxt = nodes[act, 0], nodes[act, 1], nodes[act, 2]
        hit = k == targets[act]
        offs[act[hit]] = cur[act[hit]]
        vals[act[hit]] = v[hit]
        ended = ~hit & (nxt == _NIL64)
        cont = ~hit & ~ended
        cur[act[cont]] = nxt[cont].astype(np.int64)
        live[act[hit | ended]] = False
    return offs, vals


def _lock_set(addrs) -> "np.ndarray":
    """Ascending distinct 64B-block lock representatives for a pipeline
    batch. The disambiguation set conflicts at aligned-block granularity, so
    deduping per block both avoids self-conflict (two addresses of one batch
    sharing a block would make the coroutine wait on itself) and gives a
    total acquisition order across coroutines (deadlock-free)."""
    a = np.asarray(addrs).astype(np.int64)
    return np.unique(a >> 6) << 6


def _distinct_key_batches(op_order, op_keys, k: int):
    """Split ops into pipeline batches of <= k with pairwise-distinct keys.
    Ops whose key already appears in the current batch are deferred to a
    later batch (relative per-key order preserved), so concurrent chases in
    one batch never race on the same key — each batch acquires its key set
    once, in ascending order (total-order locking: deadlock-free even with
    K locks held across coroutines)."""
    remaining = list(op_order)
    while remaining:
        batch, used, deferred = [], set(), []
        for oi in remaining:
            key = int(op_keys[oi])
            if len(batch) < k and key not in used:
                batch.append(oi)
                used.add(key)
            else:
                deferred.append(oi)
        yield np.asarray(batch, np.int64)
        remaining = deferred


# =========================================================================
# HJ — hash join probe (LLP) with software disambiguation (Table 5)
# =========================================================================
@_workload("HJ", profile=IterationProfile(insts=24, chase=1.5, mlp_cap=11,
                                          local_cycles=57),
           vector=True, pipelined=True, locked=True,
           description="hash join probe, 32B nodes, load factor 1")
def build_hj(seed: int = 0, build_keys: int = 4096, buckets: int = 4096,
             probes: int = 2048, coroutines: int = 256, vector: bool = False,
             pipeline_k: int = 16) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    keys, vals, heads, nodes = _build_chains(rng, build_keys, buckets)
    mem = nodes.view(np.uint8).copy()
    probe_keys = keys[rng.integers(0, build_keys, size=probes)]
    probe_payload = rng.integers(1, 1 << 62, size=probes, dtype=np.uint64)
    joined = np.zeros(probes, np.uint64)

    def task(c: int, ps: Iterable[int]):
        spm = c * _NODE
        for pi in ps:
            target = int(probe_keys[pi])
            head = int(heads[target % buckets])   # bucket array is local
            yield ctx.cost(insts=6)                   # hash + bucket index
            yield ctx.acquire(head if head >= 0 else 0)
            if head >= 0:
                _, v = yield from _chase_chain(spm, head, target)
                joined[pi] = np.uint64(v) ^ probe_payload[pi]
                # materialize the output tuple (partition buffer write)
                yield ctx.cost(insts=20, cycles=35)
            yield ctx.release(head if head >= 0 else 0)

    def vtask(c: int, ps: "np.ndarray"):
        base = c * pipeline_k * _NODE          # one node slot per chase
        for batch in _distinct_key_batches(ps, probe_keys, pipeline_k):
            targets = probe_keys[batch]
            locks = _lock_set(np.maximum(heads[targets % buckets], 0))
            yield ctx.cost(insts=6 * batch.size)
            yield ctx.acquire_vec(locks)       # one hop, ascending order
            _, v = yield from _chase_chain_vec(
                base, heads[targets % buckets], targets)
            joined[batch] = v ^ probe_payload[batch]
            yield ctx.cost(insts=20 * batch.size, cycles=35 * batch.size)
            yield ctx.release_vec(locks)

    if vector:
        coroutines = min(coroutines, 32)
    psplit = np.array_split(np.arange(probes), coroutines)
    if vector:
        tasks = [vtask(c, ps) for c, ps in enumerate(psplit) if len(ps)]
    else:
        tasks = [task(c, list(ps)) for c, ps in enumerate(psplit) if len(ps)]
    kv = dict(zip(keys.tolist(), vals.tolist()))
    expect = np.array([kv[int(k)] for k in probe_keys],
                      np.uint64) ^ probe_payload

    def verify(mem_out: np.ndarray) -> bool:
        return bool(np.array_equal(joined, expect))

    cfg = _vec_cfg(_NODE, coroutines, pipeline_k) if vector else _cfg(_NODE)
    inst = WorkloadInstance("HJ", mem, tasks, probes, cfg, verify,
                            vector=vector)
    inst.disambiguation = True
    return inst


# =========================================================================
# HT — ASCYLIB-style chained hash table, 50/50 lookup/update (RLP, disamb)
# =========================================================================
@_workload("HT", profile=IterationProfile(insts=26, chase=2, stores=1,
                                          local_frac=0.1, mlp_cap=14,
                                          local_cycles=57),
           vector=True, pipelined=True, locked=True,
           description="chained hash table 50/50 lookup/update")
def build_ht(seed: int = 0, n_keys: int = 4096, buckets: int = 2048,
             ops: int = 2048, coroutines: int = 256,
             hot_frac: float = 0.04, vector: bool = False,
             pipeline_k: int = 16) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    keys, vals, heads, nodes = _build_chains(rng, n_keys, buckets)
    mem = nodes.view(np.uint8).copy()
    # skewed (YCSB-zipf-like) key popularity: `hot_frac` of ops hit one hot
    # key, so conflicting ops serialize through the disambiguation waiter
    # queue — this drives Table 5's latency-dependent overhead fraction.
    op_keys = keys[rng.integers(0, n_keys, size=ops)]
    hot = rng.random(ops) < hot_frac
    op_keys[hot] = keys[0]
    op_upd = rng.random(ops) < 0.5
    op_delta = rng.integers(1, 1 << 30, size=ops, dtype=np.uint64)
    lookups = np.zeros(ops, np.uint64)

    def task(c: int, os_: Iterable[int]):
        spm = c * _NODE
        for oi in os_:
            target = int(op_keys[oi])
            head = int(heads[target % buckets])
            yield ctx.cost(insts=6)
            yield ctx.acquire(target)             # key-granular conflict set
            off, v = yield from _chase_chain(spm, head, target)
            if op_upd[oi]:
                newv = np.uint64(v) + op_delta[oi]
                yield ctx.spm_write(spm + 8, newv.tobytes())
                yield ctx.astore(spm + 8, off + 8, 8)  # value field RMW
            else:
                lookups[oi] = v
            yield ctx.release(target)

    def vtask(c: int, os_: "np.ndarray"):
        base = c * pipeline_k * _NODE
        # distinct-key batches: same-key RMWs never chase concurrently, so
        # per-key serialization (and the final sum of deltas) is preserved
        for batch in _distinct_key_batches(os_, op_keys, pipeline_k):
            targets = op_keys[batch]
            locks = _lock_set(targets)
            yield ctx.cost(insts=6 * batch.size)
            yield ctx.acquire_vec(locks)           # one hop, ascending order
            offs, v = yield from _chase_chain_vec(
                base, heads[targets % buckets], targets)
            upd = op_upd[batch]
            ui = np.nonzero(upd)[0]
            for i in ui:                           # value-field RMW per slot
                newv = v[i] + op_delta[batch[i]]
                yield ctx.spm_write(int(base + i * _NODE + 8),
                                    np.uint64(newv).tobytes())
            if ui.size:
                yield ctx.astore_vec(base + ui * _NODE + 8,
                                     offs[ui] + 8, 8, wait=True)
            lookups[batch[~upd]] = v[~upd]
            yield ctx.release_vec(locks)

    if vector:
        coroutines = min(coroutines, 32)
    osplit = np.array_split(np.arange(ops), coroutines)
    if vector:
        tasks = [vtask(c, o) for c, o in enumerate(osplit) if len(o)]
    else:
        tasks = [task(c, list(o)) for c, o in enumerate(osplit) if len(o)]

    expect_vals = dict(zip(keys.tolist(), vals.tolist()))
    expect_lookup = np.zeros(ops, np.uint64)
    for oi in range(ops):
        k = int(op_keys[oi])
        if op_upd[oi]:
            expect_vals[k] = np.uint64(expect_vals[k] + op_delta[oi])
        else:
            expect_lookup[oi] = expect_vals[k]
    key_to_node = {int(k): i for i, k in enumerate(keys)}

    def verify(mem_out: np.ndarray) -> bool:
        got = mem_out.view(np.uint64)
        for k, v in expect_vals.items():
            if got[4 * key_to_node[k] + 1] != v:
                return False
        # lookups see *some* serialized prefix value; only check final state +
        # lookups of never-updated keys
        updated_keys = set(op_keys[op_upd].tolist())
        for oi in range(ops):
            if not op_upd[oi] and int(op_keys[oi]) not in updated_keys:
                if lookups[oi] != expect_lookup[oi]:
                    return False
        return True

    cfg = _vec_cfg(_NODE, coroutines, pipeline_k) if vector else _cfg(_NODE)
    inst = WorkloadInstance("HT", mem, tasks, ops, cfg, verify,
                            vector=vector)
    inst.disambiguation = True
    return inst


# =========================================================================
# LL — hand-over-hand linked list lookup (RLP, deep dependent chase)
# =========================================================================
@_workload("LL", profile=IterationProfile(insts=2200, chase=200,
                                          local_cycles=40),
           vector=True, pipelined=True,
           description="hand-over-hand list lookup (~200-node chase)")
def build_ll(seed: int = 0, list_len: int = 400, lookups: int = 96,
             coroutines: int = 96, vector: bool = False,
             pipeline_k: int = 16) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    keys = np.sort(_unique_keys(rng, list_len))
    vals = rng.integers(1, 1 << 62, size=list_len, dtype=np.uint64)
    order = rng.permutation(list_len)          # nodes scattered in memory
    pos_of = np.empty(list_len, np.int64)
    pos_of[order] = np.arange(list_len)
    nodes = np.zeros(list_len * 4, np.uint64)
    for i in range(list_len):                  # list order = sorted keys
        p = pos_of[i]
        nodes[4 * p + 0] = keys[i]
        nodes[4 * p + 1] = vals[i]
        nxt = pos_of[i + 1] * _NODE if i + 1 < list_len else 0xFFFFFFFFFFFFFFFF
        nodes[4 * p + 2] = np.uint64(nxt)
    mem = nodes.view(np.uint8).copy()
    head = int(pos_of[0] * _NODE)
    q_idx = rng.integers(0, list_len, size=lookups)
    found = np.zeros(lookups, np.uint64)

    def task(c: int, qs: Iterable[int]):
        spm = c * _NODE
        for qi in qs:
            target = int(keys[q_idx[qi]])
            off = head
            while off != -1:
                yield ctx.aload(spm, off, _NODE)
                data = yield ctx.spm_read(spm, _NODE)
                k, v, nxt, _ = data.view(np.uint64)
                yield ctx.cost(insts=10)
                if k == target:
                    found[qi] = v
                    break
                if k > target:
                    break
                off = -1 if nxt == _NIL64 else int(nxt)

    def vtask(c: int, qs: "np.ndarray"):
        # K hand-over-hand chases, software-pipelined: a finished chase's
        # slot is refilled with the next lookup immediately (LL holds no
        # locks, so refill cannot deadlock), keeping the AloadVec width at K
        # until the queue drains instead of degenerating with the batch.
        # The sorted-key early exit (k > target) retires a chase exactly
        # where the scalar port stops, so far traffic stays pinned.
        base = c * pipeline_k * _NODE
        tq = keys[q_idx[qs]]                   # per-lookup targets
        nq = len(qs)
        prime = min(pipeline_k, nq)
        slot_q = np.full(pipeline_k, -1, np.int64)   # lookup index per slot
        slot_q[:prime] = np.arange(prime)
        cur = np.full(pipeline_k, head, np.int64)
        nexti = prime
        act = np.arange(prime)                 # active slots, kept up to date
        while act.size:
            yield ctx.aload_vec(base + act * _NODE, cur[act], _NODE, wait=True)
            data = yield ctx.spm_read(base, pipeline_k * _NODE)
            nodes = data.view(np.uint64).reshape(pipeline_k, 4)
            yield ctx.cost(insts=10 * act.size)
            sub = nodes[act]                   # one gather for k/v/nxt cols
            k, v, nxt = sub[:, 0], sub[:, 1], sub[:, 2]
            t = tq[slot_q[act]]
            hit = k == t
            found[qs[slot_q[act[hit]]]] = v[hit]
            stop = hit | (k > t) | (nxt == _NIL64)
            cur[act[~stop]] = nxt[~stop].astype(np.int64)
            refills = []
            for s in act[stop]:                # refill retired slots
                if nexti < nq:
                    slot_q[s] = nexti
                    cur[s] = head
                    nexti += 1
                    refills.append(s)
            act = act[~stop]
            if refills:
                act = np.concatenate([act, np.asarray(refills, np.int64)])

    if vector:
        # keep the scalar port's total chase concurrency (`coroutines`), but
        # fold it into coroutines-of-K so every slot refills many times —
        # the pipeline only pays off when each task streams lookups through
        # its K slots, not when it holds exactly one batch
        coroutines = max(1, min(coroutines, lookups) // pipeline_k)
    qsplit = np.array_split(np.arange(lookups), coroutines)
    if vector:
        tasks = [vtask(c, q) for c, q in enumerate(qsplit) if len(q)]
    else:
        tasks = [task(c, list(q)) for c, q in enumerate(qsplit) if len(q)]
    expect = vals[q_idx]

    def verify(mem_out: np.ndarray) -> bool:
        return bool(np.array_equal(found, expect))

    cfg = _vec_cfg(_NODE, coroutines, pipeline_k) if vector else _cfg(_NODE)
    return WorkloadInstance("LL", mem, tasks, lookups, cfg, verify,
                            vector=vector)


# =========================================================================
# SL — skip-list lookup (RLP): 32B payload + 15 pointers per node (160B)
# =========================================================================
_SL_LEVELS = 15
_SL_NODE = 160  # 32B payload (key,val,meta) + 15 * 8B forward pointers


@_workload("SL", profile=IterationProfile(insts=200, chase=22,
                                          local_frac=0.3, local_cycles=60),
           vector=True, pipelined=True,
           description="skip-list lookup, 160B nodes")
def build_sl(seed: int = 0, n_keys: int = 2048, lookups: int = 512,
             coroutines: int = 128, vector: bool = False,
             pipeline_k: int = 16) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    keys = np.sort(_unique_keys(rng, n_keys, lo=2))
    vals = rng.integers(1, 1 << 62, size=n_keys, dtype=np.uint64)
    levels = np.minimum(1 + rng.geometric(0.5, size=n_keys), _SL_LEVELS)
    NIL = np.uint64(0xFFFFFFFFFFFFFFFF)
    # node i (0 = sentinel head, key 0 < all keys, full height) at byte
    # offset i*_SL_NODE; u64 layout: [key, val, level, pad, fwd[0..14], pad]
    total = n_keys + 1
    u = np.zeros(total * (_SL_NODE // 8), np.uint64)
    node_level = np.concatenate([[_SL_LEVELS],
                                 levels.astype(np.int64)])
    node_keys = np.concatenate([np.zeros(1, np.uint64), keys])
    node_vals = np.concatenate([np.zeros(1, np.uint64), vals])
    for i in range(total):
        base = i * 20
        u[base + 0], u[base + 1] = node_keys[i], node_vals[i]
        u[base + 2] = np.uint64(node_level[i])
        for lv in range(_SL_LEVELS):
            u[base + 4 + lv] = NIL
    last_at_level = [0] * _SL_LEVELS   # sentinel heads every level
    for i in range(1, total):          # nodes already in key order
        for lv in range(int(node_level[i])):
            u[last_at_level[lv] * 20 + 4 + lv] = np.uint64(i * _SL_NODE)
            last_at_level[lv] = i
    mem = u.view(np.uint8).copy()
    q_idx = rng.integers(0, n_keys, size=lookups)
    found = np.zeros(lookups, np.uint64)

    def read_node(spm, off):
        yield ctx.aload(spm, off, _SL_NODE)
        data = yield ctx.spm_read(spm, _SL_NODE)
        return data.view(np.uint64)

    def task(c: int, qs: Iterable[int]):
        # two slots per coroutine: SpmRead views alias live SPM, and the
        # rejected-probe path keeps using `node` after the NEXT fetch — so
        # each fetch lands in the slot NOT holding the current node
        # (double-buffering instead of a per-node copy)
        base = c * 2 * _SL_NODE
        for qi in qs:
            target = keys[q_idx[qi]]
            cur = 0
            node = yield from read_node(base, 0)    # sentinel into slot 0
            yield ctx.cost(insts=6)
            for lv in range(_SL_LEVELS - 1, -1, -1):
                while True:
                    nxt = node[4 + lv]
                    if nxt == NIL:
                        break
                    nxt_node = yield from read_node(
                        base + (1 - cur) * _SL_NODE, int(nxt))
                    yield ctx.cost(insts=8)
                    if nxt_node[0] <= target:
                        node = nxt_node
                        cur = 1 - cur
                    else:
                        break
                if node[0] == target:
                    break
            if node[0] == target:
                found[qi] = node[1]

    _ROW = _SL_NODE // 8

    def vtask(c: int, qs: "np.ndarray"):
        # K skip-list descents, software-pipelined (slot refill — SL holds
        # no locks). Level moves that need no far fetch (NIL forward
        # pointers) resolve locally; each round AloadVec's the next node of
        # every live chase. The current node is snapshotted per chase
        # (`node[si] = rows[si]`): the slot window is overwritten every
        # round, and a rejected probe must keep the prior node — the
        # documented copy-on-overwrite case of the zero-copy contract. The
        # fetch sequence (and far traffic) per lookup is identical to the
        # scalar port's.
        base = c * pipeline_k * _SL_NODE
        tq = keys[q_idx[qs]]
        nq = len(qs)
        prime = min(pipeline_k, nq)
        slot_q = np.full(pipeline_k, -1, np.int64)
        slot_q[:prime] = np.arange(prime)
        nexti = prime
        node = np.zeros((pipeline_k, _ROW), np.uint64)  # per-chase snapshot
        lv = np.zeros(pipeline_k, np.int64)             # level cursor
        fetch = np.zeros(pipeline_k, np.int64)          # next offset (=sentinel)
        sentinel = np.ones(pipeline_k, bool)
        live = slot_q >= 0

        def finish(si):
            """Chase in slot `si` ended: record a hit, refill or retire."""
            nonlocal nexti
            if node[si, 0] == tq[slot_q[si]]:
                found[qs[slot_q[si]]] = node[si, 1]
            if nexti < nq:
                slot_q[si] = nexti
                fetch[si] = 0
                sentinel[si] = True
                nexti += 1
            else:
                live[si] = False

        while live.any():
            act = np.nonzero(live)[0]
            yield ctx.aload_vec(base + act * _SL_NODE, fetch[act],
                                _SL_NODE, wait=True)
            data = yield ctx.spm_read(base, pipeline_k * _SL_NODE)
            rows = data.view(np.uint64).reshape(pipeline_k, _ROW)
            n_sent = int(sentinel[act].sum())
            yield ctx.cost(insts=6 * n_sent + 8 * (act.size - n_sent))
            for si in act:
                got = rows[si]
                target = tq[slot_q[si]]
                if sentinel[si]:
                    node[si] = got                   # snapshot (see above)
                    sentinel[si] = False
                    lv[si] = _SL_LEVELS - 1
                elif got[0] <= target:
                    node[si] = got                   # accept, stay at level
                elif node[si, 0] == target:
                    finish(si)                       # reject -> hit
                    continue
                else:
                    lv[si] -= 1                      # reject -> descend
                # local descent to the next fetchable forward pointer
                while lv[si] >= 0:
                    nxt = node[si, 4 + lv[si]]
                    if nxt != NIL:
                        fetch[si] = int(nxt)
                        break
                    if node[si, 0] == target:
                        break
                    lv[si] -= 1
                else:
                    finish(si)                       # levels exhausted
                    continue
                if node[si, 4 + lv[si]] == NIL:      # stopped on hit check
                    finish(si)

    if vector:
        # fold the scalar port's concurrency into coroutines-of-K (see the
        # LL port): each task streams lookups through refilled slots
        coroutines = max(1, min(coroutines, lookups) // pipeline_k)
    qsplit = np.array_split(np.arange(lookups), coroutines)
    if vector:
        tasks = [vtask(c, q) for c, q in enumerate(qsplit) if len(q)]
    else:
        tasks = [task(c, list(q)) for c, q in enumerate(qsplit) if len(q)]
    expect = vals[q_idx]

    def verify(mem_out: np.ndarray) -> bool:
        return bool(np.array_equal(found, expect))

    if vector:
        cfg = _vec_cfg(_SL_NODE, coroutines, pipeline_k,
                       data_bytes=coroutines * pipeline_k * _SL_NODE)
    else:
        cfg = _cfg(_SL_NODE,
                   spm_bytes=_fit_spm(coroutines * 2 * _SL_NODE, 256))
    return WorkloadInstance("SL", mem, tasks, lookups, cfg, verify,
                            vector=vector)


# =========================================================================
# BFS — Graph500-style level-synchronous BFS (frontier parallelism)
# =========================================================================
@_workload("BFS", profile=IterationProfile(insts=12, chase=1, indep_loads=1,
                                           stores=0.4, local_frac=0.2,
                                           mlp_cap=10, local_cycles=30),
           vector=True, frontier=True,
           description="level-synchronous BFS per-edge unit")
def build_bfs(seed: int = 0, n_vertices: int = 2048, n_edges: int = 32768,
              coroutines: int = 224, vector: bool = False) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, size=n_edges)
    dst = rng.integers(0, n_vertices, size=n_edges)
    # undirected CSR
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    order = np.argsort(u, kind="stable")
    u, v = u[order], v[order]
    degs = np.bincount(u, minlength=n_vertices)
    offs = np.zeros(n_vertices + 1, np.int64)
    np.cumsum(degs, out=offs[1:])
    adj = v.astype(np.int32)
    # far memory: [adjacency int32 array | parent int64 array]
    adj_bytes = adj.size * 4
    parent = np.full(n_vertices, -1, np.int64)
    root = int(u[0])
    parent[root] = root
    mem = np.concatenate([adj.view(np.uint8),
                          parent.view(np.uint8)]).copy()
    par_off = adj_bytes
    CHUNK = 60  # neighbors per aload (240B; last 8B of the slot = parent slot)

    next_frontier: set = set()

    def expand(c: int, vertices: List[int]):
        spm = c * 256
        pslot = spm + 248
        for uu in vertices:
            lo, hi = int(offs[uu]), int(offs[uu + 1])
            yield ctx.cost(insts=8)
            for base in range(lo, hi, CHUNK):
                cnt = min(CHUNK, hi - base)
                yield ctx.aload(spm, base * 4, cnt * 4)
                data = yield ctx.spm_read(spm, cnt * 4)
                neigh = data.view(np.int32)
                yield ctx.cost(insts=4 * cnt)
                for vv in neigh:
                    vv = int(vv)
                    yield ctx.aload(pslot, par_off + vv * 8, 8)
                    pdata = yield ctx.spm_read(pslot, 8)
                    if pdata.view(np.int64)[0] == -1:
                        yield ctx.spm_write(pslot, np.int64(uu).tobytes())
                        yield ctx.astore(pslot, par_off + vv * 8, 8)
                        next_frontier.add(vv)
                    yield ctx.cost(insts=6)

    # vector port SPM layout per coroutine: 240B neighbor chunk | 8B parent
    # staging slot (holds uu for the AstoreVec scatter) | CHUNK parent slots
    VSLOT = 768

    def vexpand(c: int, vertices: List[int]):
        nbase = c * VSLOT
        stage = nbase + 240
        pbase = nbase + 248
        for uu in vertices:
            lo, hi = int(offs[uu]), int(offs[uu + 1])
            yield ctx.cost(insts=8)
            for base in range(lo, hi, CHUNK):
                cnt = min(CHUNK, hi - base)
                yield ctx.aload(nbase, base * 4, cnt * 4)
                data = yield ctx.spm_read(nbase, cnt * 4)
                neigh = data.view(np.int32).astype(np.int64)
                yield ctx.cost(insts=4 * cnt)
                # one vector fetch of every neighbor's parent word
                yield ctx.aload_vec(pbase + np.arange(cnt) * 8,
                                    par_off + neigh * 8, 8, wait=True)
                pdata = yield ctx.spm_read(pbase, cnt * 8)
                parents = pdata.view(np.int64)
                yield ctx.cost(insts=6 * cnt)
                claim = np.unique(neigh[parents == -1])
                if claim.size:
                    # scatter `uu` from one staging slot to every claimed
                    # parent word (repeated SPM source, vector of targets)
                    yield ctx.spm_write(stage, np.int64(uu).tobytes())
                    yield ctx.astore_vec(np.full(claim.size, stage),
                                         par_off + claim * 8, 8, wait=True)
                    next_frontier.update(int(vv) for vv in claim)

    if vector:
        coroutines = min(coroutines, 64)

    # level-synchronous driver is run by the caller via `rounds`
    def make_round_tasks(frontier: List[int]) -> List:
        next_frontier.clear()
        fsplit = np.array_split(np.array(frontier, dtype=np.int64),
                                min(coroutines, max(1, len(frontier))))
        mk = vexpand if vector else expand
        return [mk(c, list(f)) for c, f in enumerate(fsplit) if len(f)]

    # reference BFS distances
    dist = np.full(n_vertices, -1, np.int64)
    dist[root] = 0
    frontier = [root]
    d = 0
    while frontier:
        nxt = []
        for uu in frontier:
            for k in range(int(offs[uu]), int(offs[uu + 1])):
                vv = int(adj[k])
                if dist[vv] == -1:
                    dist[vv] = d + 1
                    nxt.append(vv)
        frontier = nxt
        d += 1

    cfg = _cfg(256, queue_length=1024) if vector else _cfg(256)
    inst = WorkloadInstance("BFS", mem, [], 2 * n_edges, cfg, lambda m: True,
                            vector=vector)
    inst.make_round_tasks = make_round_tasks            # type: ignore
    inst.next_frontier = next_frontier                  # type: ignore
    inst.root = root                                    # type: ignore

    def verify(mem_out: np.ndarray) -> bool:
        got_parent = mem_out[par_off:par_off + n_vertices * 8].view(np.int64)
        # every reachable vertex has a parent that is exactly one level closer
        for vv in range(n_vertices):
            if dist[vv] > 0:
                p = got_parent[vv]
                if p < 0 or dist[int(p)] != dist[vv] - 1:
                    return False
            if dist[vv] == -1 and got_parent[vv] != -1:
                return False
        return True

    inst.verify = verify
    return inst


# =========================================================================
# IS — NAS integer sort (bucket counting): sequential key blocks (LLP)
# =========================================================================
@_workload("IS", profile=IterationProfile(insts=400, indep_loads=8,
                                          sequential=True, mlp_cap=48,
                                          local_cycles=320),
           vector=True,
           description="bucket counting over sequential 512B key blocks")
def build_is(seed: int = 0, n_keys: int = 65536, block: int = 128,
             coroutines: int = 32, n_buckets: int = 1024,
             vector: bool = False, vec_chunk: int = 8) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_buckets, size=n_keys).astype(np.int32)
    mem = keys.view(np.uint8).copy()
    hist = np.zeros(n_buckets, np.int64)      # histogram kept local (cached)
    gran = block * 4
    blocks = n_keys // block

    def task(c: int, lo: int, hi: int):
        spm = c * gran
        for blk in range(lo, hi):
            yield ctx.aload(spm, blk * gran, gran)
            data = yield ctx.spm_read(spm, gran)
            np.add.at(hist, data.view(np.int32), 1)
            yield ctx.cost(insts=3 * block)

    def vtask(c: int, lo: int, hi: int):
        base = c * vec_chunk * gran
        for b0 in range(lo, hi, vec_chunk):
            cnt = min(vec_chunk, hi - b0)
            yield ctx.aload_vec(base + np.arange(cnt) * gran,
                                np.arange(b0, b0 + cnt) * gran, gran,
                                wait=True)
            data = yield ctx.spm_read(base, cnt * gran)
            np.add.at(hist, data.view(np.int32), 1)
            yield ctx.cost(insts=3 * block * cnt)

    if vector:
        coroutines = min(coroutines, 8)
    bounds = np.linspace(0, blocks, coroutines + 1).astype(int)
    mk = vtask if vector else task
    tasks = [mk(c, bounds[c], bounds[c + 1]) for c in range(coroutines)]
    expect = np.bincount(keys, minlength=n_buckets)

    def verify(mem_out: np.ndarray) -> bool:
        return bool(np.array_equal(hist, expect))

    cfg = _vec_cfg(gran, coroutines, vec_chunk,
                   data_bytes=coroutines * vec_chunk * gran) if vector \
        else _cfg(gran)
    return WorkloadInstance("IS", mem, tasks, blocks, cfg, verify,
                            vector=vector)


# =========================================================================
# HPCG — sparse matrix-vector product y = A x (LLP; mixed granularity)
# =========================================================================
@_workload("HPCG", profile=IterationProfile(insts=140, indep_loads=33,
                                            local_frac=0.15, mlp_cap=40,
                                            local_cycles=120),
           vector=True,
           description="SpMV row: 352B row data + 27 x-gathers")
def build_hpcg(seed: int = 0, rows: int = 2048, nnz_per_row: int = 27,
               coroutines: int = 64, vector: bool = False,
               vec_rows: int = 4) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, rows, size=(rows, nnz_per_row)).astype(np.int32)
    vals = rng.standard_normal((rows, nnz_per_row))
    x = rng.standard_normal(rows)
    # far layout: [row data: per row 27*(i32 col + f64 val) packed | x | y]
    row_pad = 352  # 27*12=324 -> pad to 352 for alignment
    packed = np.zeros(rows * row_pad, np.uint8)
    for r in range(rows):
        base = r * row_pad
        packed[base:base + nnz_per_row * 4] = cols[r].view(np.uint8)
        packed[base + nnz_per_row * 4:base + nnz_per_row * 4 + nnz_per_row * 8] \
            = vals[r].view(np.uint8)
    x_off = rows * row_pad
    y_off = x_off + rows * 8
    mem = np.concatenate([packed, x.view(np.uint8),
                          np.zeros(rows * 8, np.uint8)]).copy()

    def task(c: int, lo: int, hi: int):
        spm = c * 512
        xs = spm + 352
        for r in range(lo, hi):
            yield ctx.aload(spm, r * row_pad, row_pad)
            data = yield ctx.spm_read(spm, row_pad)
            rc = data[:nnz_per_row * 4].view(np.int32)
            rv = data[nnz_per_row * 4:
                      nnz_per_row * 4 + nnz_per_row * 8].view(np.float64)
            acc = 0.0
            # gather x entries: independent 8B aloads, 16 slots in flight
            rids = []
            for j in range(min(16, len(rc))):
                rid = yield ctx.aload(xs + j * 8, x_off + int(rc[j]) * 8,
                                      8, wait=False)
                rids.append(rid)
            for j in range(len(rc)):
                yield ctx.await_rid(rids[j])
                xd = yield ctx.spm_read(xs + (j % 16) * 8, 8)
                acc += rv[j] * xd.view(np.float64)[0]
                yield ctx.cost(insts=4)
                if j + 16 < len(rc):   # refill the freed slot
                    rid = yield ctx.aload(xs + (j % 16) * 8,
                                          x_off + int(rc[j + 16]) * 8, 8,
                                          wait=False)
                    rids.append(rid)
            yield ctx.spm_write(spm, np.float64(acc).tobytes())
            yield ctx.astore(spm, y_off + r * 8, 8)

    def vtask(c: int, lo: int, hi: int):
        # per-coroutine SPM layout: vec_rows row slots | vec_rows*27 x-slots
        # | vec_rows y-slots.  Row gather -> one AloadVec per batch of rows.
        stride = vec_rows * (row_pad + nnz_per_row * 8 + 8)
        rbase = c * stride
        xbase = rbase + vec_rows * row_pad
        ybase = xbase + vec_rows * nnz_per_row * 8
        for r0 in range(lo, hi, vec_rows):
            cnt = min(vec_rows, hi - r0)
            yield ctx.aload_vec(rbase + np.arange(cnt) * row_pad,
                                (r0 + np.arange(cnt)) * row_pad, row_pad,
                                wait=True)
            rcs, rvs = [], []
            for i in range(cnt):
                data = yield ctx.spm_read(rbase + i * row_pad, row_pad)
                rcs.append(data[:nnz_per_row * 4].view(np.int32))
                rvs.append(data[nnz_per_row * 4:
                                nnz_per_row * 4 + nnz_per_row * 8]
                           .view(np.float64))
            cols_flat = np.concatenate(rcs).astype(np.int64)
            yield ctx.aload_vec(xbase + np.arange(cnt * nnz_per_row) * 8,
                                x_off + cols_flat * 8, 8, wait=True)
            xdata = yield ctx.spm_read(xbase, cnt * nnz_per_row * 8)
            xv = xdata.view(np.float64)
            accs = np.empty(cnt)
            for i in range(cnt):
                acc = 0.0
                for j in range(nnz_per_row):   # scalar-port accumulation order
                    acc += rvs[i][j] * xv[i * nnz_per_row + j]
                accs[i] = acc
                yield ctx.cost(insts=4 * nnz_per_row)
            yield ctx.spm_write(ybase, accs)
            yield ctx.astore_vec(ybase + np.arange(cnt) * 8,
                                 y_off + (r0 + np.arange(cnt)) * 8, 8,
                                 wait=True)

    if vector:
        coroutines = min(coroutines, 8)
    bounds = np.linspace(0, rows, coroutines + 1).astype(int)
    mk = vtask if vector else task
    tasks = [mk(c, bounds[c], bounds[c + 1]) for c in range(coroutines)]
    expect = np.einsum("rj,rj->r", vals, x[cols])

    def verify(mem_out: np.ndarray) -> bool:
        got = mem_out[y_off:y_off + rows * 8].view(np.float64)
        return bool(np.allclose(got, expect))

    cfg = _cfg(512, queue_length=1024) if vector else _cfg(512)
    return WorkloadInstance("HPCG", mem, tasks, rows, cfg, verify,
                            vector=vector)


# =========================================================================
# Redis — YCSB-B-style KV service: local buckets, far collision lists (RLP)
# =========================================================================
@_workload("Redis", profile=IterationProfile(insts=40, chase=1.5,
                                             stores=0.05, mlp_cap=11,
                                             local_cycles=70),
           vector=True, pipelined=True, locked=True, distinct=True,
           description="YCSB-B KV: local buckets, far collision lists")
def build_redis(seed: int = 0, n_keys: int = 4096, buckets: int = 4096,
                ops: int = 2048, coroutines: int = 256,
                update_frac: float = 0.05, vector: bool = False,
                pipeline_k: int = 16,
                distinct: bool = False) -> WorkloadInstance:
    rng = np.random.default_rng(seed)
    keys, vals, heads, nodes = _build_chains(rng, n_keys, buckets)
    mem = nodes.view(np.uint8).copy()
    op_keys = keys[rng.integers(0, n_keys, size=ops)]
    op_upd = rng.random(ops) < update_frac
    if distinct:
        # at most one update per key (later conflicting updates demoted to
        # lookups): final far-memory bytes become schedule-independent, so
        # differential tests can pin vector runs to the scalar port exactly
        seen: set = set()
        for oi in np.nonzero(op_upd)[0]:
            k = int(op_keys[oi])
            if k in seen:
                op_upd[oi] = False
            else:
                seen.add(k)
    op_newval = rng.integers(1, 1 << 62, size=ops, dtype=np.uint64)
    got_vals = np.zeros(ops, np.uint64)

    def task(c: int, os_: Iterable[int]):
        spm = c * _NODE
        for oi in os_:
            target = int(op_keys[oi])
            head = int(heads[target % buckets])    # bucket array local
            yield ctx.cost(insts=10)                   # parse request + hash
            yield ctx.acquire(target)
            off, v = yield from _chase_chain(spm, head, target)
            if op_upd[oi]:
                yield ctx.spm_write(spm + 8, op_newval[oi].tobytes())
                yield ctx.astore(spm + 8, off + 8, 8)
            else:
                got_vals[oi] = v
            yield ctx.release(target)
            yield ctx.cost(insts=8)                    # format reply

    def vtask(c: int, os_: "np.ndarray"):
        base = c * pipeline_k * _NODE
        for batch in _distinct_key_batches(os_, op_keys, pipeline_k):
            targets = op_keys[batch]
            locks = _lock_set(targets)
            yield ctx.cost(insts=10 * batch.size)
            yield ctx.acquire_vec(locks)           # one hop, ascending order
            offs, v = yield from _chase_chain_vec(
                base, heads[targets % buckets], targets)
            upd = op_upd[batch]
            ui = np.nonzero(upd)[0]
            for i in ui:
                yield ctx.spm_write(int(base + i * _NODE + 8),
                                    op_newval[batch[i]].tobytes())
            if ui.size:
                yield ctx.astore_vec(base + ui * _NODE + 8,
                                     offs[ui] + 8, 8, wait=True)
            got_vals[batch[~upd]] = v[~upd]
            yield ctx.release_vec(locks)
            yield ctx.cost(insts=8 * batch.size)

    if vector:
        coroutines = min(coroutines, 32)
    osplit = np.array_split(np.arange(ops), coroutines)
    if vector:
        tasks = [vtask(c, o) for c, o in enumerate(osplit) if len(o)]
    else:
        tasks = [task(c, list(o)) for c, o in enumerate(osplit) if len(o)]

    final = dict(zip(keys.tolist(), vals.tolist()))
    for oi in range(ops):
        if op_upd[oi]:
            final[int(op_keys[oi])] = op_newval[oi]
    key_to_node = {int(k): i for i, k in enumerate(keys)}

    def verify(mem_out: np.ndarray) -> bool:
        got = mem_out.view(np.uint64)
        # final value of every updated key must be one of the writes or orig
        for oi in range(ops):
            k = int(op_keys[oi])
            node_val = got[4 * key_to_node[k] + 1]
            cand = {int(vals[key_to_node[k]])} | {
                int(op_newval[j]) for j in range(ops)
                if op_upd[j] and int(op_keys[j]) == k}
            if int(node_val) not in cand:
                return False
        return True

    cfg = _vec_cfg(_NODE, coroutines, pipeline_k) if vector else _cfg(_NODE)
    inst = WorkloadInstance("Redis", mem, tasks, ops, cfg, verify,
                            vector=vector)
    inst.disambiguation = True
    return inst


# =========================================================================
# Registration lives on the builders (@_workload above each): one entry per
# workload in repro.amu.REGISTRY, carrying the builder, the baseline
# IterationProfile, and declared capabilities (vector/pipelined/locked/
# distinct/frontier, LLVM rebuild kwargs).
#
# Profiles: `mlp_cap`/`local_cycles` pairs for the additive (Little's-law)
# baseline mode are FITTED against the paper's Table 4 curves (GUPS, HJ,
# STREAM) and transferred to structurally similar workloads; window-mode
# profiles (chase-dominated) derive concurrency from ROB/LSQ occupancy.
# =========================================================================
