"""Rack-scale arbitration: N per-core scheduler stacks, one far memory.

A rack run instantiates N complete engine+SPM+scheduler stacks (one per
core, each driving its own workload port with a private request-ID space)
over ONE shared :class:`~repro.core.farmem.FarMemoryModel`, so the far
model's per-link serialization points, backpressure heaps and fault
streams become genuine cross-core contention. The
:class:`RackArbiter` here is the determinism keystone:

* **Global-clock order.** Every scheduler turn is a
  :meth:`~repro.core.coroutines.Scheduler.step` call, and the arbiter
  always steps the live core with the **smallest core clock** (`sched.t`),
  breaking ties by **core index** (lowest first). A core's clock never
  decreases, so the shared far model sees the N command streams merged in
  a near-sorted order that is a pure function of (config, seed) — link
  free-time evolution, latency/fault RNG draws and ledger accumulation
  order are all reproducible bit-for-bit across runs.
* **cores=1 identity.** With one core the policy degenerates to
  `while live: step()`, which is literally the body of
  ``Scheduler.run`` — a single-core rack run is bit-identical (trace,
  stats, RNG bitstreams, summary) to today's ``AmuSession``.
* **Attribution.** The far model's request/byte/fault counters are
  global; the arbiter brackets each step with counter snapshots and a
  ``far.client`` tag, attributing every delta (and every serialized
  channel cycle, via ``FarMemoryModel.link_busy``) to the core that
  issued it. Attribution is pure accounting — it never feeds timing.

`repro.amu.RackSession` owns the config/registry side (per-core workload
builds with independently spawned seeds, per-core `RunStats`,
`RackStats` aggregation); this module is deliberately free of any
workload or config knowledge.
"""
from __future__ import annotations

import time
from typing import List, Sequence

from repro.core.coroutines import Scheduler
from repro.core.farmem import FarMemoryModel


class RackArbiter:
    """Deterministic time-sliced interleaver over per-core schedulers.

    The schedulers must all share ``far`` as their engines' far-memory
    model (a single-element list is fine — that is the ``cores=1``
    identity path). Call :meth:`run` after spawning each core's tasks on
    its own scheduler.
    """

    def __init__(self, far: FarMemoryModel,
                 schedulers: Sequence[Scheduler]) -> None:
        if not schedulers:
            raise ValueError("RackArbiter needs at least one scheduler")
        self.far = far
        self.schedulers: List[Scheduler] = list(schedulers)
        n = len(self.schedulers)
        # per-core attribution of the shared far model's global counters
        self.requests = [0] * n
        self.bytes_moved = [0] * n
        self.errors = [0] * n
        self.timeouts = [0] * n
        self.steps = [0] * n
        self.wall_us = [0.0] * n

    @property
    def makespan(self) -> float:
        """Rack completion time: the slowest core's clock, cycles."""
        return max(s.t for s in self.schedulers)

    def run(self) -> None:
        """Interleave scheduler turns in (clock, core-index) order until
        every core's tasks have finished."""
        far = self.far
        scheds = self.schedulers
        live = [i for i, s in enumerate(scheds) if s.live > 0]
        while live:
            best = live[0]
            bt = scheds[best].t
            for i in live[1:]:         # strict < keeps the lowest index
                if scheds[i].t < bt:   # on clock ties (the arbiter rule)
                    best, bt = i, scheds[i].t
            s = scheds[best]
            far.client = best
            r0, b0 = far.requests, far.bytes_moved
            e0, t0 = far.errors, far.timeouts
            w0 = time.perf_counter()
            s.step()
            self.wall_us[best] += (time.perf_counter() - w0) * 1e6
            self.steps[best] += 1
            self.requests[best] += far.requests - r0
            self.bytes_moved[best] += far.bytes_moved - b0
            self.errors[best] += far.errors - e0
            self.timeouts[best] += far.timeouts - t0
            if s.live <= 0:
                live.remove(best)
        far.client = 0
