"""Serving driver: batched prefill + decode loop with the paged KV cache.

Demonstrates the AMU serving path end-to-end: requests arrive in batches,
prefill fills the cache, decode streams tokens; with --use-kernels the
decode attention runs the paged_attention Pallas kernel (interpret mode on
CPU, compiled on TPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    assert cfg.is_decoder, f"{args.arch} is encoder-only; nothing to decode"
    key = jax.random.PRNGKey(0)
    params = lm.init_model(cfg, key)
    max_len = args.prompt_len + args.max_new

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)))

    cache = lm.init_cache(cfg, args.batch, max_len)
    prefill = jax.jit(lambda p, b, c: lm.prefill(
        cfg, p, b, c, use_kernels=args.use_kernels))
    decode = jax.jit(lambda p, t, c: lm.decode_step(
        cfg, p, t, c, use_kernels=args.use_kernels))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    t_prefill = time.time() - t0

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], -1)[:, None]
        return jax.random.categorical(
            k, logits[:, -1] / args.temperature)[:, None]

    tok = sample(logits, key)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.max_new - 1):
        logits, cache = decode(params, tok, cache)
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    tok_s = args.batch * (args.max_new - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s | "
          f"decode: {tok_s:,.1f} tok/s | sample row 0: "
          f"{np.asarray(gen[0])[:12].tolist()}")


if __name__ == "__main__":
    main()
