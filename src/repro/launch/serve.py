"""Serving driver: batched prefill + decode loop with the paged KV cache.

Demonstrates the AMU serving path end-to-end: requests arrive in batches,
prefill fills the cache, decode streams tokens; with --use-kernels the
decode attention runs the paged_attention Pallas kernel (interpret mode on
CPU, compiled on TPU).

With --offload-kv the KV cache lives in host memory between decode steps
(:class:`~repro.runtime.offload.OffloadedKVCache`): each step fetches the
cache pages through the resident window (prefetch-ahead, AMI-style), runs
decode, and update()s the new pages back. The driver decodes once without
offload and once with, and asserts the generated tokens are identical —
the runtime twin of the simulator's `paged_kv_serve` differential check.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--offload-kv", action="store_true",
                    help="page the KV cache through OffloadedKVCache "
                         "between decode steps and check token identity")
    ap.add_argument("--offload-window", type=int, default=2,
                    help="resident window (device pages) for --offload-kv")
    ap.add_argument("--drain-timeout-s", type=float, default=30.0,
                    help="wall-clock budget for the --offload-kv prefetch "
                         "drain; a hung worker fails the run with a "
                         "diagnostic instead of hanging CI")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    assert cfg.is_decoder, f"{args.arch} is encoder-only; nothing to decode"
    key = jax.random.PRNGKey(0)
    params = lm.init_model(cfg, key)
    max_len = args.prompt_len + args.max_new

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)))

    cache = lm.init_cache(cfg, args.batch, max_len)
    prefill = jax.jit(lambda p, b, c: lm.prefill(
        cfg, p, b, c, use_kernels=args.use_kernels))
    decode = jax.jit(lambda p, t, c: lm.decode_step(
        cfg, p, t, c, use_kernels=args.use_kernels))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    t_prefill = time.time() - t0

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], -1)[:, None]
        return jax.random.categorical(
            k, logits[:, -1] / args.temperature)[:, None]

    def run_decode(cache, kv=None):
        """Decode loop; with `kv`, the cache pages through host memory
        between steps (fetch -> decode -> update). JAX arrays are
        immutable, so the post-prefill cache is reusable across runs."""
        k = key
        tok = sample(logits, k)
        out, cur = [tok], cache
        if kv is not None:
            leaves, treedef = jax.tree.flatten(cur)
            for i, leaf in enumerate(leaves):
                kv.host_put(i, jax.device_get(leaf))
            kv.prefetch(0)
        for _ in range(args.max_new - 1):
            if kv is not None:
                pages = [kv.fetch(i) for i in range(kv.num_layers)]
                cur = jax.tree.unflatten(treedef, pages)
            lg, cur = decode(params, tok, cur)
            if kv is not None:
                for i, leaf in enumerate(jax.tree.leaves(cur)):
                    kv.update(i, leaf)
            k, sub = jax.random.split(k)
            tok = sample(lg, sub)
            out.append(tok)
        jax.block_until_ready(tok)
        return jnp.concatenate(out, axis=1)

    t0 = time.time()
    gen = run_decode(cache)
    t_decode = time.time() - t0
    tok_s = args.batch * (args.max_new - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s | "
          f"decode: {tok_s:,.1f} tok/s | sample row 0: "
          f"{np.asarray(gen[0])[:12].tolist()}")

    if args.offload_kv:
        from repro.runtime.offload import OffloadedKVCache

        n_pages = len(jax.tree.leaves(cache))
        kv = OffloadedKVCache(num_layers=n_pages,
                              window=args.offload_window)
        t0 = time.time()
        gen_off = run_decode(cache, kv=kv)
        t_off = time.time() - t0
        # drain under a wall-clock watchdog: close() blocks on in-flight
        # uploads and the writeback queue, so one wedged worker would
        # otherwise hang the CI step with no diagnostic
        drain = threading.Thread(target=kv.close, daemon=True)
        drain.start()
        drain.join(timeout=args.drain_timeout_s)
        if drain.is_alive():
            raise SystemExit(
                f"offload-kv drain hung: close() still blocked after "
                f"{args.drain_timeout_s:.1f}s (pending uploads: "
                f"{sorted(kv._pending)}, writebacks queued: "
                f"{kv._writeback_q.unfinished_tasks})")
        same = bool(jnp.array_equal(gen, gen_off))
        print(f"offload-kv: {n_pages} pages, window {args.offload_window}, "
              f"{t_off:.2f}s | stats {kv.stats} | tokens identical: {same}")
        if not same:
            raise SystemExit("offloaded decode diverged from baseline")


if __name__ == "__main__":
    main()
