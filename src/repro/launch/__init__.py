# NOTE: dryrun is intentionally not imported here — it sets XLA_FLAGS at
# import time and must be launched as its own process (python -m
# repro.launch.dryrun).
from repro.launch.mesh import make_debug_mesh, make_production_mesh
