"""While-aware roofline accounting over compiled (SPMD-partitioned) HLO.

`compiled.cost_analysis()` counts every while body **once**, which silently
drops ~97% of the FLOPs of a scanned-layer model (36-64 trips) and all of a
sequence scan's work. This module parses `compiled.as_text()` into
computations, recovers each while's trip count from its condition, and sums

* **flops**   — 2 * prod(result) * prod(contracted dims) per `dot`
                (including dots inside fusion computations), weighted by the
                product of enclosing while trip counts;
* **hbm_bytes** — per-instruction operand+result bytes over the control
                computations (post-fusion, each instruction ~= one kernel, so
                inputs+outputs approximate HBM traffic), same weighting;
* **ici_bytes** — collective payload bytes (x2 for all-reduce: ring
                reduce-scatter + all-gather), same weighting, split by kind.

All shapes in the partitioned module are per-device, so every total is
per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16, "f32": 4,
                "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*?)\s+([\w\-\$]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*(?:\([^{]*)?\{\s*$")

# Traffic allowlist: on the TPU target, elementwise chains fuse into their
# producers/consumers; the ops below are the ones that actually move HBM
# bytes (matmuls, explicit data movement, reductions, fusions, collectives).
_TRAFFIC_OPS = {"dot", "fusion", "convolution", "copy", "transpose",
                "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
                "slice", "concatenate", "pad", "reduce", "reduce-window",
                "sort", "rng", "rng-bit-generator", "cholesky",
                "triangular-solve", "all-gather", "all-reduce",
                "reduce-scatter", "all-to-all", "collective-permute"}
_COLLECTIVE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0,
                      "reduce-scatter": 1.0, "all-to-all": 1.0,
                      "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str                      # operand list + attributes


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # symbol table


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(stripped)
            if m and "(" in stripped:
                cur = Computation(m.group(1))
            continue
        if stripped == "}" or stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, rtype, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, rtype, opcode, rest))
            cur.types[name] = rtype
    return comps


def _while_links(comp: Computation) -> List[Tuple[str, str]]:
    """(cond_comp, body_comp) pairs for while instrs in `comp`."""
    out = []
    for ins in comp.instrs:
        if ins.opcode == "while":
            c = re.search(r"condition=(%[\w\.\-]+)", ins.rest)
            b = re.search(r"body=(%[\w\.\-]+)", ins.rest)
            if c and b:
                out.append((c.group(1), b.group(1)))
    return out


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the condition computation (scan bound)."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.match(r"\s*([0-9]+)\s*\)?", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _called_comps(ins: Instr) -> List[str]:
    out = []
    for key in ("calls=", "to_apply="):
        for m in re.finditer(key + r"(%[\w\.\-]+)", ins.rest):
            out.append(m.group(1))
    return out


def _operand_names(ins: Instr) -> List[str]:
    # operands come before the closing paren of the op call; attributes
    # follow after "), ". Take the prefix up to the first ")," or final ")".
    depth = 1
    end = len(ins.rest)
    for i, ch in enumerate(ins.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%[\w\.\-]+", ins.rest[:end])


def _dot_flops(ins: Instr, comp: Computation) -> float:
    result_dims = _shape_dims(ins.result_type)
    ops = _operand_names(ins)
    if not ops:
        return 0.0
    lhs_type = comp.types.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    contract = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)]
    n = 1
    for d in result_dims:
        n *= d
    return 2.0 * n * contract


def _sliced_param_bytes(param_name: str, comp: Computation) -> Optional[int]:
    """If `param_name` is only consumed through (dynamic-)slice ops inside
    `comp`, the fusion reads just the slices — return their total bytes.
    None -> consumed in full."""
    total = 0
    used_whole = False
    used = False
    for ins in comp.instrs:
        ops = _operand_names(ins)
        if param_name not in ops:
            continue
        used = True
        if ins.opcode in ("dynamic-slice", "slice") and ops \
                and ops[0] == param_name:
            total += _shape_bytes(ins.result_type)
        elif ins.opcode == "dynamic-update-slice" and ops \
                and ops[0] == param_name:
            # pass-through destination: in-place update writes the update
            # operand only
            if len(ops) > 1:
                total += _shape_bytes(comp.types.get(ops[1], ""))
        else:
            used_whole = True
    if used and not used_whole:
        return total
    return None


def _instr_traffic(ins: Instr, comp: Computation,
                   comps: Dict[str, Computation]) -> float:
    """HBM bytes for one (possibly fused) kernel: result + operands, with
    slice-aware accounting — a kernel that reads `dynamic-slice(stack)` or
    writes `dynamic-update-slice(stack, upd)` touches only the slice, not
    the whole carried stack."""
    if ins.opcode == "dynamic-slice" or ins.opcode == "slice":
        return 2.0 * _shape_bytes(ins.result_type)      # read + write slice
    if ins.opcode == "dynamic-update-slice":
        ops = _operand_names(ins)
        upd = _shape_bytes(comp.types.get(ops[1], "")) if len(ops) > 1 else 0
        return 2.0 * upd
    result = _shape_bytes(ins.result_type)
    operands = 0.0
    if ins.opcode == "fusion":
        subs = _called_comps(ins)
        sub = comps.get(subs[0]) if subs else None
        op_names = _operand_names(ins)
        # map operand position -> fusion parameter name
        params = {}
        if sub is not None:
            for sins in sub.instrs:
                if sins.opcode == "parameter":
                    m = re.match(r"\s*([0-9]+)", sins.rest)
                    if m:
                        params[int(m.group(1))] = sins.name
            # root DUS -> in-place write of the update only
            root = sub.instrs[-1] if sub.instrs else None
            if root is not None and root.opcode == "dynamic-update-slice":
                rops = _operand_names(root)
                if len(rops) > 1:
                    result = _shape_bytes(sub.types.get(rops[1], ""))
        for i, op_name in enumerate(op_names):
            full = _shape_bytes(comp.types.get(op_name, ""))
            if sub is not None and i in params:
                sliced = _sliced_param_bytes(params[i], sub)
                if sliced is not None:
                    operands += min(sliced, full)
                    continue
            operands += full
    else:
        for op_name in _operand_names(ins):
            operands += _shape_bytes(comp.types.get(op_name, ""))
    return result + operands


@dataclass
class RooflineCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    by_collective: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    while_trips: Dict[str, int] = field(default_factory=dict)
    dot_flops_top: List[Tuple[str, float]] = field(default_factory=list)


def analyze(hlo: str, entry: Optional[str] = None) -> RooflineCounts:
    comps = parse_computations(hlo)
    # entry computation: the one named like main / entry
    if entry is None:
        cands = [n for n in comps if "main" in n or "entry" in n.lower()]
        entry = cands[0] if cands else max(
            comps, key=lambda n: len(comps[n].instrs))

    out = RooflineCounts()
    # weights: control comps (entry + while bodies); fusions inherit weight
    control_weight: Dict[str, float] = {entry: 1.0}
    stack = [entry]
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        w = control_weight[cname]
        for cond_name, body_name in _while_links(comp):
            trips = _trip_count(comps[cond_name]) if cond_name in comps else 1
            out.while_trips[body_name] = trips
            control_weight[body_name] = control_weight.get(body_name, 0.0) \
                + w * trips
            stack.append(body_name)

    dot_log: Dict[str, float] = {}
    for cname, w in control_weight.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            # ---- FLOPs: dots here + dots inside fusions -------------------
            if ins.opcode == "dot":
                f = w * _dot_flops(ins, comp)
                out.flops += f
                dot_log[f"{cname}/{ins.name}"] = f
            elif ins.opcode == "fusion":
                for sub in _called_comps(ins):
                    subc = comps.get(sub)
                    if subc is None:
                        continue
                    for sins in subc.instrs:
                        if sins.opcode == "dot":
                            f = w * _dot_flops(sins, subc)
                            out.flops += f
                            dot_log[f"{cname}/{ins.name}/{sins.name}"] = f
            # ---- HBM traffic ---------------------------------------------
            if ins.opcode.replace("-start", "") in _TRAFFIC_OPS:
                out.hbm_bytes += w * _instr_traffic(ins, comp, comps)
            # ---- collectives ----------------------------------------------
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVE_FACTOR and not ins.opcode.endswith("-done"):
                payload = _shape_bytes(ins.result_type) \
                    * _COLLECTIVE_FACTOR[base]
                out.ici_bytes += w * payload
                out.by_collective[base] = out.by_collective.get(base, 0.0) \
                    + w * payload
                out.collective_count += 1
    out.dot_flops_top = sorted(dot_log.items(), key=lambda kv: -kv[1])[:20]
    return out
