import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from functools import partial  # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import KIND_PREFILL, KIND_TRAIN  # noqa: E402
from repro.data.pipeline import input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import hints  # noqa: E402
from repro.runtime import sharding as shd  # noqa: E402
from repro.runtime import steps as steps_mod  # noqa: E402

# --------------------------------------------------------------- HW constants
PEAK_FLOPS = 197e12        # bf16 / chip (v5e-class)
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,            # reduce-scatter + all-gather ring cost
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


# --------------------------------------------------------------- cell builder
def build_cell(arch: str, shape_name: str, mesh, par=None,
               moe_mode: str = "capacity", microbatches: int = 0,
               params_bf16: bool = False):
    """Returns (lower_fn, arg_specs) for one (arch x shape x mesh) cell."""
    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    ok, reason = configs.shape_applicable(cfg, shape)
    if not ok:
        return None, reason
    par = par or configs.default_parallel(cfg, shape)
    if microbatches:
        import dataclasses
        par = dataclasses.replace(par, microbatches=microbatches)

    key = jax.random.PRNGKey(0)
    # >=100B-param configs hold weights in bf16 (f32 masters would exceed
    # the fleet's HBM; the optimizer keeps f32 math on bf16 moments)
    p_dtype = (jnp.bfloat16 if (cfg.param_count() > 100e9 or params_bf16)
               else jnp.float32)
    params_sds = jax.eval_shape(lambda: lm.init_model(cfg, key,
                                                      dtype=p_dtype))
    p_sh = shd.params_shardings(cfg, par, mesh, params_sds)
    params_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_sds, p_sh)
    b_sh = shd.batch_shardings(cfg, par, mesh, shape)
    batch_sds = input_specs(cfg, shape, sharding_fn=lambda n: None)
    batch_sds = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype,
        sharding=b_sh.get(k if k in b_sh else "tokens"))
        for k, v in batch_sds.items()}

    if shape.kind == KIND_TRAIN:
        moment_dtype = (jnp.bfloat16 if cfg.param_count() > 100e9
                        else jnp.float32)
        opt_sds = jax.eval_shape(
            partial(adamw.init_state, moment_dtype=moment_dtype), params_sds)
        o_sh = shd.opt_state_shardings(cfg, par, mesh, params_sds)
        opt_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            opt_sds, o_sh)
        opt_cfg = adamw.AdamWConfig()
        step = steps_mod.make_train_step(cfg, par, opt_cfg,
                                         use_kernels=False,
                                         moe_mode=moe_mode)
        fn = jax.jit(step, out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
    elif shape.kind == KIND_PREFILL:
        cache_sds = None
        if cfg.is_decoder:
            cache_sds = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
            c_sh = shd.cache_shardings(cfg, par, mesh, cache_sds)
            cache_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                cache_sds, c_sh)
            step = steps_mod.make_prefill_step(cfg, par, moe_mode=moe_mode)
            fn = jax.jit(step, donate_argnums=(2,))
            args = (params_sds, batch_sds, cache_sds)
        else:
            # encoder-only: full forward, no cache
            def enc_fwd(params, batch):
                return lm.prefill(cfg, params, batch, None,
                                  moe_mode=moe_mode)[0]
            fn = jax.jit(enc_fwd)
            args = (params_sds, batch_sds)
    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
        c_sh = shd.cache_shardings(cfg, par, mesh, cache_sds)
        # pretend the cache is full (len = seq_len) — shapes are what matter
        cache_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            cache_sds, c_sh)
        step = steps_mod.make_serve_step(cfg, par, moe_mode=moe_mode)
        fn = jax.jit(step, donate_argnums=(2,))
        args = (params_sds, batch_sds["tokens"], cache_sds)
    return (fn, args), ""


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             moe_mode: str = "capacity",
             microbatches: int = 0,
             params_bf16: bool = False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    hints.set_mesh(mesh)
    t0 = time.time()
    built, reason = build_cell(arch, shape_name, mesh, moe_mode=moe_mode,
                               microbatches=microbatches,
                               params_bf16=params_bf16)
    if built is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": True, "reason": reason}
    fn, args = built
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    hints.set_mesh_axes(None)
    counts = hlo_analysis.analyze(hlo)
    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    tokens = shape.tokens_per_step
    n_active = cfg.active_param_count()
    mult = 6 if shape.kind == KIND_TRAIN else 2
    model_flops = mult * n_active * tokens
    flops_dev = counts.flops
    bytes_dev = counts.hbm_bytes
    coll_dev = counts.ici_bytes
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "chips": chips, "skipped": False,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "mem": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_gb": (mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes) / 1e9,
        },
        "flops_per_dev": flops_dev,
        "bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "collectives": dict(counts.by_collective),
        "collective_count": counts.collective_count,
        "while_trips": dict(counts.while_trips),
        "cost_analysis_flops": float(cost.get("flops", 0.0)),
        "cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "model_flops_total": model_flops,
        "terms": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        },
        "useful_flops_ratio": (model_flops / chips) / max(flops_dev, 1.0),
    }
    terms = result["terms"]
    result["bottleneck"] = max(terms, key=terms.get)
    result["roofline_frac"] = max(
        result["useful_flops_ratio"] * terms["compute_s"] / max(sum(terms.values()), 1e-12), 0.0)
    return result


def apply_tuning(tune) -> None:
    """--tune rwkv.impl=chunked attn.q_chunk=1024 ... (perf iterations)."""
    from repro.models import blocks as _blocks
    from repro.models import rwkv6 as _rwkv6
    from repro.models import moe as _moe
    from repro.models import lm as _lm
    targets = {"attn": _blocks.ATTN_CONFIG, "rwkv": _rwkv6.RWKV_CONFIG,
               "moe": _moe.MOE_CONFIG, "lm": _lm.LM_CONFIG}
    for item in tune:
        key, _, val = item.partition("=")
        group, _, field = key.partition(".")
        cfgd = targets[group]
        old = cfgd[field]
        cfgd[field] = type(old)(int(val) if isinstance(old, int)
                                else float(val) if isinstance(old, float)
                                else val)
        print(f"# tune {group}.{field} = {cfgd[field]}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--moe-mode", default="capacity")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--params-bf16", action="store_true")
    ap.add_argument("--tune", action="append", default=[],
                    help="perf knobs, e.g. rwkv.impl=chunked "
                         "attn.chunk_threshold=4096 moe.sharded=1")
    ap.add_argument("--out", default="",
                    help="append JSONL results here")
    args = ap.parse_args()
    archs = configs.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    apply_tuning(args.tune)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    res = run_cell(arch, shape_name, mp,
                                   moe_mode=args.moe_mode,
                                   microbatches=args.microbatches,
                                   params_bf16=args.params_bf16)
                except Exception as e:  # noqa: BLE001 — report and continue
                    res = {"arch": arch, "shape": shape_name,
                           "multi_pod": mp, "error": repr(e)[:500],
                           "skipped": False}
                    failures += 1
                line = json.dumps(res)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
    if failures:
        print(f"FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
