"""Production meshes. Functions only — importing this module never touches
jax device state."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """`axis_types=` (and `jax.sharding.AxisType`) only exist on newer jax;
    older releases default every axis to Auto, which is what we want."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(devices: int = 8):
    """Small mesh for CPU tests: (devices//2, 2) ("data", "model")."""
    assert devices % 2 == 0
    return _make_mesh((devices // 2, 2), ("data", "model"))
