"""End-to-end training driver: config -> mesh -> sharded state -> supervised
loop with async checkpointing, straggler monitoring, and restart recovery.

On the CPU container this runs reduced configs on a debug mesh; on a real
cluster the same driver runs the production mesh (see dryrun.py for the
compile-only proof at 256/512 chips).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import synthetic_batch
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.runtime import sharding as shd
from repro.runtime import steps as steps_mod
from repro.runtime.ft import StepMonitor, TrainSupervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    shape = configs.ShapeConfig("cli_train", args.seq, args.batch,
                                configs.KIND_TRAIN)
    par = configs.ParallelConfig(remat="full",
                                 microbatches=args.microbatches)
    if args.production_mesh:
        mesh = make_production_mesh()
    elif jax.device_count() > 1:
        mesh = make_debug_mesh(min(8, jax.device_count()))
    else:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt_cfg = adamw.AdamWConfig(total_steps=args.steps)

    key = jax.random.PRNGKey(0)
    with mesh:
        params = lm.init_model(cfg, key)
        p_sh = shd.params_shardings(cfg, par, mesh, params)
        params = jax.device_put(params, p_sh)
        opt_state = adamw.init_state(params)
        o_sh = shd.opt_state_shardings(cfg, par, mesh, params)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(
            steps_mod.make_train_step(cfg, par, opt_cfg),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))

        store = CheckpointStore(args.checkpoint_dir)
        monitor = StepMonitor(on_straggler=lambda s, d, e: print(
            f"[straggler] step {s}: {d:.3f}s vs ewma {e:.3f}s"))
        sup = TrainSupervisor(store, checkpoint_every=args.checkpoint_every,
                              monitor=monitor)
        start = 0
        if args.resume and store.latest_step() is not None:
            # restore leaves directly onto their target shardings (elastic:
            # the writer's mesh/layout is irrelevant)
            sh_tree = {"params": p_sh, "opt_state": o_sh}
            flat, _ = jax.tree_util.tree_flatten_with_path(sh_tree)
            lookup = {jax.tree_util.keystr(path): sh for path, sh in flat}
            restored, extra = store.restore(
                store.latest_step(),
                {"params": params, "opt_state": opt_state},
                sharding_fn=lambda key, leaf: lookup[key])
            params, opt_state = restored["params"], restored["opt_state"]
            start = extra["step"]
            print(f"resumed from step {start}")

        def batch_fn(step):
            return {k: jnp.asarray(v) for k, v in
                    synthetic_batch(cfg, shape, step).items()}

        t0 = time.time()
        state = sup.run({"params": params, "opt_state": opt_state,
                         "step": start},
                        step_fn, batch_fn, args.steps)
        dt = time.time() - t0
        loss = float(state["metrics"]["loss"])
        tok_s = (args.steps - start) * shape.tokens_per_step / max(dt, 1e-9)
        print(f"done: {args.steps} steps, final loss {loss:.4f}, "
              f"{tok_s:,.0f} tok/s, stragglers={len(monitor.stragglers)}")


if __name__ == "__main__":
    main()
