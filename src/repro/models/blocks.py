"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention, GLU MLPs.

Pure-JAX (functional, pytree params). Attention dispatches to the Pallas
flash/paged kernels via `repro.kernels.ops` when enabled, else the jnp
reference path. Every init matches the assigned architectures' knobs
(QKV bias, GQA kv heads, sliding window, M-RoPE sections, tied embeddings).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime import hints

Params = Dict[str, Any]


# --------------------------------------------------------------------- init
def _dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


# -------------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, dim: int, dtype=jnp.float32) -> Params:
    p = {"scale": jnp.ones((dim,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] or [3, B, S] for M-RoPE.

    M-RoPE (Qwen2-VL): the D/2 rotary frequencies are split into
    (temporal, height, width) sections, each rotated by its own position id.
    For text tokens the three position streams coincide and M-RoPE reduces
    to standard RoPE.
    """
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                     # [D/2]
    if mrope_sections and positions.ndim == 3:
        sec = jnp.cumsum(jnp.array((0,) + tuple(mrope_sections)))
        # section id per frequency -> which of the 3 position streams to use
        stream = jnp.zeros((D // 2,), jnp.int32)
        for i in range(len(mrope_sections)):
            stream = jnp.where((jnp.arange(D // 2) >= sec[i])
                               & (jnp.arange(D // 2) < sec[i + 1]), i, stream)
        # per-frequency positions: [B, S, D/2]
        pos = jnp.moveaxis(positions.astype(jnp.float32), 0, -1)  # [B,S,3]
        pos = jnp.take_along_axis(
            pos, jnp.broadcast_to(stream[None, None, :],
                                  pos.shape[:2] + (D // 2,)), axis=-1)
        angles = pos * freqs[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[0]
        angles = positions.astype(jnp.float32)[..., None] * freqs  # [B,S,D/2]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)    # [B,S,1,D/2]
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., : D // 2], x[..., D // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------- attention
def init_attention(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(kq, d, cfg.num_heads * hd, dtype),
        "wk": _dense_init(kk, d, cfg.num_kv_heads * hd, dtype),
        "wv": _dense_init(kv, d, cfg.num_kv_heads * hd, dtype),
        "wo": _dense_init(ko, cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def _attn_mask(S: int, T: int, causal: bool, window: int,
               q_offset: int) -> jnp.ndarray:
    """[S, T] boolean mask. T = total KV length; queries at q_offset..+S."""
    q_pos = jnp.arange(S)[:, None] + q_offset
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    return mask


# runtime-tunable attention execution knobs (perf iterations mutate these)
ATTN_CONFIG = {
    "chunk_threshold": 8192,   # S >= threshold -> chunked (flash-style) path
    "q_chunk": 512,
    "kv_chunk": 1024,
    "pad_heads": 0,            # pad q heads per KV group to a mesh multiple
}


def _chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       causal: bool, window: int) -> jnp.ndarray:
    """Pure-JAX flash attention: double scan over query/key chunks with
    running softmax stats — O(S) memory instead of O(S^2). Lowers on any
    backend (the Pallas kernel is the TPU-optimized twin).

    q: [B, S, H, D] (grouped/repeated to q heads already), k/v same H.
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    qc = min(ATTN_CONFIG["q_chunk"], S)
    kc = min(ATTN_CONFIG["kv_chunk"], T)
    nq, nk = S // qc, T // kc
    scale = 1.0 / math.sqrt(D)
    qs = jnp.moveaxis(q.reshape(B, nq, qc, H, D), 1, 0)     # [nq,B,qc,H,D]
    ks = jnp.moveaxis(k.reshape(B, nk, kc, H, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kc, H, D), 1, 0)

    def q_block(_, qi_q):
        qi, qb = qi_q                                        # qb [B,qc,H,D]
        q32 = qb.astype(jnp.float32)

        def kv_block(carry, ki_kv):
            m, l, acc = carry
            ki, kb, vb = ki_kv
            logits = jnp.einsum("bqhd,bkhd->bhqk", q32,
                                kb.astype(jnp.float32)) * scale
            q_pos = qi * qc + jax.lax.broadcasted_iota(
                jnp.int32, (qc, kc), 0)
            k_pos = ki * kc + jax.lax.broadcasted_iota(
                jnp.int32, (qc, kc), 1)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= k_pos <= q_pos
            if window:
                mask &= k_pos > q_pos - window
            logits = jnp.where(mask[None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, -1))      # [B,H,qc]
            alpha = jnp.exp(m - m_new)
            pr = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + jnp.sum(pr, -1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", pr, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # [B,H,qc,D]
        return None, jnp.moveaxis(out, 1, 2).astype(q.dtype)  # [B,qc,H,D]

    _, blocks_out = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    return jnp.moveaxis(blocks_out, 0, 1).reshape(B, S, H, D)


def attention(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              positions: jnp.ndarray,
              kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cache_len: Optional[jnp.ndarray] = None,
              window: int = 0,
              use_kernels: bool = False,
              return_kv: bool = False) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """GQA attention. x: [B, S, d].

    Training/prefill: kv_cache is None -> self attention over x.
    Decode: kv_cache = (k, v) with [B, T, Hkv, D]; x is the new token(s);
    `cache_len` [B] gives the valid prefix length. Returns (out, new_cache).
    """
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    # sharding hints: shard heads over "model" when divisible, else fall
    # back to sharding the sequence (keeps 28/40-head configs from
    # replicating S x S logits on every chip)
    dp = hints.batch_spec_axes()
    msize = hints.axis_size("model")
    head_ok = msize > 1 and Hq % msize == 0
    kv_ok = msize > 1 and Hkv % msize == 0
    pad_per_group = 0
    if (ATTN_CONFIG["pad_heads"] and msize > 1 and not head_ok
            and kv_cache is None):
        # pad each KV group's query heads so total q heads divide the mesh:
        # zero heads cost (pad/group)/(group) extra attention FLOPs but keep
        # K/V replicated instead of sequence-gathered every layer.
        group = Hq // Hkv
        target_group = group
        while (target_group * Hkv) % msize != 0:
            target_group += 1
        pad_per_group = target_group - group
        if pad_per_group:
            qg = q.reshape(B, S, Hkv, group, hd)
            qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad_per_group),
                              (0, 0)))
            q = qg.reshape(B, S, Hkv * target_group, hd)
            Hq = q.shape[2]
            head_ok = Hq % msize == 0
    if head_ok:
        q = hints.constrain(q, dp, None, "model", None)
        k = hints.constrain(k, dp, None, "model" if kv_ok else None, None)
        v = hints.constrain(v, dp, None, "model" if kv_ok else None, None)
    else:
        q = hints.constrain(q, dp, "model", None, None)
        k = hints.constrain(k, dp, None, None, None)
        v = hints.constrain(v, dp, None, None, None)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache                              # [B, T, Hkv, D]
        T = ck.shape[1]
        # scatter the new tokens at cache_len (decode: S == 1 typically)
        idx = (cache_len[:, None] + jnp.arange(S)[None, :])  # [B, S]
        bidx = jnp.arange(B)[:, None]
        ck = ck.at[bidx, idx].set(k.astype(ck.dtype))
        cv = cv.at[bidx, idx].set(v.astype(cv.dtype))
        new_cache = (ck, cv)
        if use_kernels and S == 1 and window == 0:
            from repro.kernels import ops as kops
            out = kops.paged_attention(q[:, 0], ck, cv, cache_len + S)
            out = out[:, None]
            out = out.reshape(B, S, Hq * hd) @ p["wo"]
            return out, new_cache
        k_all, v_all = ck, cv
        # valid-key mask (+ causal within the new tokens + window)
        k_pos = jnp.arange(T)[None, None, :]                   # [1,1,T]
        q_pos = idx[:, :, None]                                # [B,S,1]
        mask = k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        mask = mask[:, None]                                   # [B,1,S,T]
    else:
        k_all, v_all = k, v
        T = S
        if return_kv:
            new_cache = (k, v)
        if use_kernels and cfg.causal and S >= 128:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True, window=window)
            out = out.reshape(B, S, Hq * hd) @ p["wo"]
            return out, new_cache
        if S >= ATTN_CONFIG["chunk_threshold"]:
            rep = Hq // Hkv
            out = _chunked_attention(q, jnp.repeat(k, rep, axis=2),
                                     jnp.repeat(v, rep, axis=2),
                                     cfg.causal, window)
            out = out.reshape(B, S, Hq * hd) @ p["wo"]
            return out, new_cache
        mask = _attn_mask(S, T, cfg.causal, window, 0)[None, None]

    # grouped heads: repeat kv
    rep = Hq // Hkv
    k_all = jnp.repeat(k_all, rep, axis=2)
    v_all = jnp.repeat(v_all, rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bshd,bthd->bhst", q, k_all) * scale
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v_all)
    if pad_per_group:
        group = Hq // Hkv
        out = out.reshape(B, S, Hkv, group, hd)[
            :, :, :, :group - pad_per_group]
        Hq = Hkv * (group - pad_per_group)
        out = out.reshape(B, S, Hq, hd)
    out = out.reshape(B, S, Hq * hd) @ p["wo"]
    return out, new_cache


def ring_attention_step(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                        positions: jnp.ndarray, ck: jnp.ndarray,
                        cv: jnp.ndarray, cache_len: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Sliding-window decode with a ring-buffered KV cache.

    x: [B, 1, d]; ck/cv: [B, W, Hkv, D] hold the last W tokens' K/V (already
    roped at their absolute positions); cache_len: [B] tokens seen so far.
    """
    Bsz, S, d = x.shape
    assert S == 1
    hd = cfg.resolved_head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    Wn = ck.shape[1]
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q.reshape(Bsz, 1, Hq, hd), positions, cfg.rope_theta,
                   cfg.mrope_sections)
    k = apply_rope(k.reshape(Bsz, 1, Hkv, hd), positions, cfg.rope_theta,
                   cfg.mrope_sections)
    v = v.reshape(Bsz, 1, Hkv, hd)
    slot = cache_len % Wn                                   # [B]
    bidx = jnp.arange(Bsz)
    ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
    valid = jnp.arange(Wn)[None, :] <= jnp.minimum(cache_len, Wn - 1)[:, None]
    rep = Hq // Hkv
    k_all = jnp.repeat(ck, rep, axis=2)
    v_all = jnp.repeat(cv, rep, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q, k_all) / math.sqrt(hd)
    logits = jnp.where(valid[:, None, None, :], logits.astype(jnp.float32),
                       -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v_all)
    out = out.reshape(Bsz, 1, Hq * hd) @ p["wo"]
    return out, (ck, cv)


# ---------------------------------------------------------------------- MLP
def init_mlp(cfg: ModelConfig, key, dtype=jnp.float32,
             d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"w_gate": _dense_init(k1, d, ff, dtype),
                "w_up": _dense_init(k2, d, ff, dtype),
                "w_down": _dense_init(k3, ff, d, dtype)}
    return {"w_up": _dense_init(k1, d, ff, dtype),
            "w_down": _dense_init(k2, ff, d, dtype)}


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if cfg.activation == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
