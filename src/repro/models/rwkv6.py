"""RWKV-6 "Finch" token mixing (arXiv:2404.05892) — attention-free recurrence
with data-dependent decay.

Per head (head size N = cfg.resolved_head_dim), with per-token receptance r,
key k, value v and decay w_t (data-dependent, in (0,1)) and bonus u:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t            (state: [N, N])
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Train/prefill runs a chunked lax.scan over time (state carried between
chunks -> sub-quadratic, O(S * N^2) work); decode is the single-step update.
Token-shift mixing (lerp of x_{t-1}, x_t) uses a 1-token cache in decode.

Simplifications vs the reference implementation (documented): the low-rank
LoRA projections for decay/mix are collapsed into full-rank dense maps (same
FLOP order, fewer moving parts), and gating uses silu.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import _dense_init
from repro.runtime import hints

Params = Dict[str, Any]


def init_rwkv6(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "w_r": _dense_init(ks[0], d, d, dtype),
        "w_k": _dense_init(ks[1], d, d, dtype),
        "w_v": _dense_init(ks[2], d, d, dtype),
        "w_g": _dense_init(ks[3], d, d, dtype),
        "w_w": (jax.random.normal(ks[4], (d, d), jnp.float32)
                * 0.01 / math.sqrt(d)).astype(dtype),   # decay projection
        "w_o": _dense_init(ks[5], d, d, dtype),
        "mix": jax.random.uniform(ks[6], (5, d), jnp.float32).astype(dtype),
        "decay_base": (jax.random.uniform(ks[7], (d,), jnp.float32, -8.0,
                                          -4.0)).astype(jnp.float32),
        "bonus": jnp.zeros((d,), jnp.float32),
    }


def _token_shift(x: jnp.ndarray,
                 last: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x_{t-1} stream; `last` is the final token of the previous chunk."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _project(cfg: ModelConfig, p: Params, x: jnp.ndarray,
             x_prev: jnp.ndarray):
    """Compute r, k, v, gate, decay for a chunk. x: [B, S, d]."""
    mix = p["mix"]
    def lerp(i):
        return x + (x_prev - x) * mix[i]
    r = lerp(0) @ p["w_r"]
    k = lerp(1) @ p["w_k"]
    v = lerp(2) @ p["w_v"]
    g = jax.nn.silu(lerp(3) @ p["w_g"])
    # data-dependent decay (Finch): w_t = exp(-exp(base + f(x)))
    wlog = p["decay_base"] + jnp.tanh(lerp(4) @ p["w_w"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog))                       # in (0, 1)
    return r, k, v, g, w


def _heads(cfg: ModelConfig, t: jnp.ndarray) -> jnp.ndarray:
    B, S, d = t.shape
    return t.reshape(B, S, cfg.num_heads, cfg.resolved_head_dim)


# Execution knobs (perf iterations mutate these): "scan" = faithful
# per-token recurrence; "chunked" = chunk-parallel matmul form (same math,
# O(S/C) sequential steps, state written once per chunk instead of per
# token). Safe because our decay parameterization keeps w in [0.95, 1).
RWKV_CONFIG = {"impl": "scan", "chunk": 64, "mixer_bf16": 0}


def rwkv6_chunk_parallel(cfg: ModelConfig, p: Params, r, k, v, w,
                         state: jnp.ndarray, chunk: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-parallel WKV6: within a chunk of C tokens the recurrence
    unrolls into two matmuls (an intra-chunk lower-triangular 'attention'
    and a carried-state term); the state advances once per chunk.

    r/k/v/w: [B, S, H, N] (f32; w in (0,1)); state: [B, H, N, N].
    Returns (out [B, S, H, N], final state).
    """
    B, S, H, N = r.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    u = p["bonus"].reshape(H, N)
    nc = S // C

    def one_chunk(s0, inp):
        rc, kc, vc, wc = inp                       # [B, C, H, N]
        rc, kc, vc = (t.astype(jnp.float32) if t.dtype != jnp.bfloat16
                      else t for t in (rc, kc, vc))
        cw = jnp.cumprod(wc, axis=1)               # inclusive decay products
        cwe = cw / wc                              # exclusive (prod_{s<t})
        r_dec = (rc.astype(jnp.float32) * cwe).astype(rc.dtype)
        # carried-state contribution
        o_state = jnp.einsum("bchn,bhnv->bchv", r_dec,
                             s0.astype(rc.dtype),
                             preferred_element_type=jnp.float32)
        # intra-chunk strictly-causal pair contributions
        k_scaled = (kc.astype(jnp.float32) / cw).astype(kc.dtype)
        att = jnp.einsum("bchn,bshn->bhcs", r_dec, k_scaled,
                         preferred_element_type=jnp.float32)
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        o_intra = jnp.einsum("bhcs,bshv->bchv", att.astype(vc.dtype), vc,
                             preferred_element_type=jnp.float32)
        # same-token bonus
        diag = jnp.einsum("bchn,bchn->bch", rc,
                          (u[None, None] * kc.astype(jnp.float32)
                           ).astype(kc.dtype),
                          preferred_element_type=jnp.float32)
        o = o_state + o_intra + diag[..., None] * vc
        # state update: decay the carry, add this chunk's outer products
        decay_all = cw[:, -1]                      # [B, H, N]
        k_carry = (kc.astype(jnp.float32)
                   * (decay_all[:, None] / cw)).astype(kc.dtype)
        s1 = decay_all[..., None] * s0 + jnp.einsum(
            "bshn,bshv->bhnv", k_carry, vc,
            preferred_element_type=jnp.float32)
        return s1, o

    rs, ks_, vs, ws = (t.reshape(B, nc, C, H, N).swapaxes(0, 1)
                       for t in (r, k, v, w))
    s_final, outs = jax.lax.scan(one_chunk, state, (rs, ks_, vs, ws))
    out = outs.swapaxes(0, 1).reshape(B, S, H, N)
    return out, s_final


def rwkv6_chunk(cfg: ModelConfig, p: Params, r, k, v, w,
                state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential scan within a chunk. r/k/v/w: [B, S, H, N] (w f32).
    state: [B, H, N, N] (f32). Returns (out [B,S,H,N], new state)."""
    u = p["bonus"].reshape(cfg.num_heads, cfg.resolved_head_dim)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                      # [B, H, N]
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)    # [B, H, N, N]
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    new_state, out = jax.lax.scan(step, state, (rs, ks_, vs, ws))
    return jnp.moveaxis(out, 0, 1), new_state


def init_rwkv6_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    H, N = cfg.num_heads, cfg.resolved_head_dim
    return {"s": jnp.zeros((batch, H, N, N), jnp.float32),
            "last_x": jnp.zeros((batch, 1, cfg.d_model), jnp.float32)}


def apply_rwkv6(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                state: Optional[Dict[str, jnp.ndarray]] = None,
                chunk: int = 256
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """RWKV-6 block. x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    s0 = (state["s"] if state is not None
          else jnp.zeros((B, cfg.num_heads, cfg.resolved_head_dim,
                          cfg.resolved_head_dim), jnp.float32))
    last = state["last_x"].astype(x.dtype) if state is not None else None
    x_prev = _token_shift(x, last)
    r, k, v, g, w = _project(cfg, p, x, x_prev)
    rh, kh, vh = (_heads(cfg, t) for t in (r, k, v))
    wh = _heads(cfg, w.astype(jnp.float32))
    # mixer runs head-sharded over the "model" axis (64 heads / 16-way TP)
    dp = hints.batch_spec_axes()
    rh, kh, vh = (hints.constrain(t, dp, None, "model", None)
                  for t in (rh, kh, vh))
    wh = hints.constrain(wh, dp, None, "model", None)
    mix_dtype = (jnp.bfloat16 if RWKV_CONFIG.get("mixer_bf16")
                 else jnp.float32)
    rh32, kh32, vh32 = (t.astype(mix_dtype) for t in (rh, kh, vh))
    if (RWKV_CONFIG["impl"] == "chunked" and S > 1
            and S % min(RWKV_CONFIG["chunk"], S) == 0):
        out, s_new = rwkv6_chunk_parallel(cfg, p, rh32, kh32, vh32, wh, s0,
                                          RWKV_CONFIG["chunk"])
    else:
        out, s_new = rwkv6_chunk(cfg, p, rh32, kh32, vh32, wh, s0)
    out = out.astype(x.dtype).reshape(B, S, d)
    out = (out * g) @ p["w_o"]
    new_state = None
    if state is not None:
        new_state = {"s": s_new, "last_x": x[:, -1:].astype(jnp.float32)}
    return out, new_state
