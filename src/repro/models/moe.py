"""Mixture-of-Experts FFN (kimi-k2: 384e top-8 + 1 shared; granite: 32e top-8).

Two execution paths:

* **dense-routing einsum** (default; used for dry-run lowering): every token
  multiplies a [E, d, ff] stacked weight through a dispatch one-hot — the
  compiled HLO keeps the expert dimension intact so expert-parallel sharding
  (experts over the "model" axis, all-to-all dispatch) is visible to SPMD.
* **gathered path** (`capacity` mode): tokens are sorted by expert and run
  through per-expert matmuls at a capacity bound — this is what the AMU-style
  async expert streaming optimizes (experts are "far"; only the active top-k
  groups are fetched).

The router adds the standard auxiliary load-balancing loss.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import _dense_init

Params = Dict[str, Any]

# Execution knobs (perf iterations mutate these)
MOE_CONFIG = {"sharded": 0}   # 1 -> shard_map local-capacity dispatch


def init_moe(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    m = cfg.moe
    assert m is not None
    d, ff, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": _dense_init(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                   / math.sqrt(ff)).astype(dtype),
    }
    if m.num_shared_experts:
        ffs = ff * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": _dense_init(k1, d, ffs, dtype),
                       "w_up": _dense_init(k2, d, ffs, dtype),
                       "w_down": _dense_init(k3, ffs, d, dtype)}
    return p


def route(cfg: ModelConfig, p: Params,
          x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing. x: [T, d] -> (weights [T, k], experts [T, k], aux loss)."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, m.top_k)          # [T, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # aux loss: E * sum_e (fraction of tokens to e) * (mean router prob to e)
    T = x.shape[0]
    one_hot = jax.nn.one_hot(experts, m.num_experts, dtype=jnp.float32)
    frac = jnp.sum(one_hot, axis=(0, 1)) / (T * m.top_k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac * mean_prob)
    return weights.astype(x.dtype), experts, aux


def apply_moe_dense(cfg: ModelConfig, p: Params,
                    x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense-dispatch path. x: [B, S, d] -> ([B, S, d], aux_loss).

    Dispatch/combine are einsums against a [T, k, E] one-hot; XLA SPMD turns
    the expert dimension contraction into all-to-alls when experts are
    sharded over the "model" axis.
    """
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    weights, experts, aux = route(cfg, p, xt)
    one_hot = jax.nn.one_hot(experts, m.num_experts, dtype=x.dtype)  # [T,k,E]
    combine = jnp.einsum("tk,tke->te", weights, one_hot)             # [T,E]
    # dispatch every token to its experts: [E, T, d] would be huge; instead
    # contract tokens against experts blockwise: out = sum_e combine[t,e] *
    # f_e(x_t). With capacity-less dense routing we compute f_e lazily via
    # einsum over the stacked weights.
    gate = jnp.einsum("td,edf->tef", xt, p["w_gate"])
    up = jnp.einsum("td,edf->tef", xt, p["w_up"])
    act = jax.nn.silu(gate) * up                                     # [T,E,ff]
    act = act * combine[..., None]
    out = jnp.einsum("tef,efd->td", act, p["w_down"])
    if m.num_shared_experts:
        sp = p["shared"]
        out = out + (jax.nn.silu(xt @ sp["w_gate"])
                     * (xt @ sp["w_up"])) @ sp["w_down"]
    return out.reshape(B, S, d), aux


def apply_moe_capacity(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-bounded gathered path: tokens sorted by expert, per-expert
    matmuls at capacity C = ceil(T * k / E * capacity_factor). Overflowing
    tokens are dropped (standard Switch-style), making FLOPs proportional to
    *active* params — this is the path the async expert-streaming runtime
    feeds one expert group at a time."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    weights, experts, aux = route(cfg, p, xt)
    E, k = m.num_experts, m.top_k
    C = max(1, int(math.ceil(T * k / E * m.capacity_factor)))
    flat_e = experts.reshape(-1)                                  # [T*k]
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    # position of each (token, expert) pair within its expert's queue
    one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # [T*k, E]
    pos_in_e = jnp.cumsum(one_hot, axis=0) - 1
    mypos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = mypos < C
    slot = jnp.where(keep, flat_e * C + mypos, E * C)             # drop -> pad
    # scatter tokens into [E*C+1, d] buffer
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xt[flat_tok])
    grouped = buf[:E * C].reshape(E, C, d)
    gate = jnp.einsum("ecd,edf->ecf", grouped, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", grouped, p["w_up"])
    act = jax.nn.silu(gate) * up
    eout = jnp.einsum("ecf,efd->ecd", act, p["w_down"]).reshape(E * C, d)
    eout = jnp.concatenate([eout, jnp.zeros((1, d), x.dtype)], axis=0)
    tok_out = eout[slot] * (flat_w * keep)[:, None]               # [T*k, d]
    out = jnp.zeros((T, d), x.dtype).at[flat_tok].add(tok_out)
    if m.num_shared_experts:
        sp = p["shared"]
        out = out + (jax.nn.silu(xt @ sp["w_gate"])
                     * (xt @ sp["w_up"])) @ sp["w_down"]
    return out.reshape(B, S, d), aux


def apply_moe(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              mode: str = "dense") -> Tuple[jnp.ndarray, jnp.ndarray]:
    if mode == "capacity":
        if MOE_CONFIG.get("sharded"):
            return apply_moe_sharded(cfg, p, x)
        return apply_moe_capacity(cfg, p, x)
    return apply_moe_dense(cfg, p, x)


def apply_moe_sharded(cfg: ModelConfig, p: Params, x: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel dispatch with LOCAL capacity via shard_map.

    Tokens are batch-sharded over the data axes and replicated over "model";
    experts are sharded over "model". Each device routes its local tokens,
    dispatches only to its local expert group at a local capacity bound
    (buffers scale with tokens/device, not global tokens), runs the expert
    FFNs, and psums the partial combine over "model" — one all-reduce per
    layer instead of global-capacity gather/scatter traffic.
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime import hints

    mesh = hints.get_mesh()
    m = cfg.moe
    E = m.num_experts
    msize = hints.axis_size("model")
    if mesh is None or msize <= 1 or E % msize != 0:
        return apply_moe_capacity(cfg, p, x)
    dp = hints.batch_spec_axes()
    E_local = E // msize

    def local_fn(xl, router, wg, wu, wd, shared):
        Bl, Sl, d = xl.shape
        Tl = Bl * Sl
        xt = xl.reshape(Tl, d)
        logits = (xt.astype(jnp.float32) @ router)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, experts = jax.lax.top_k(probs, m.top_k)
        weights = (weights / jnp.sum(weights, -1, keepdims=True)
                   ).astype(xl.dtype)
        one_hot_all = jax.nn.one_hot(experts, E, dtype=jnp.float32)
        frac = jnp.sum(one_hot_all, axis=(0, 1)) / (Tl * m.top_k)
        aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
        # local expert group
        e0 = jax.lax.axis_index("model") * E_local
        eloc = experts - e0                                    # [Tl, k]
        mine = (eloc >= 0) & (eloc < E_local)
        C = max(1, int(math.ceil(Tl * m.top_k / E * m.capacity_factor)))
        flat_e = jnp.where(mine, eloc, E_local).reshape(-1)    # [Tl*k]
        flat_w = (weights * mine).reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(Tl), m.top_k)
        oh = jax.nn.one_hot(flat_e, E_local, dtype=jnp.int32)
        mypos = jnp.take_along_axis(
            jnp.cumsum(oh, axis=0) - 1,
            jnp.minimum(flat_e, E_local - 1)[:, None], axis=1)[:, 0]
        keep = (mypos < C) & (flat_e < E_local)
        slot = jnp.where(keep, flat_e * C + mypos, E_local * C)
        buf = jnp.zeros((E_local * C + 1, d), xl.dtype).at[slot].set(
            xt[flat_tok])
        grouped = buf[:E_local * C].reshape(E_local, C, d)
        gate = jnp.einsum("ecd,edf->ecf", grouped, wg)
        up = jnp.einsum("ecd,edf->ecf", grouped, wu)
        act = jax.nn.silu(gate) * up
        eout = jnp.einsum("ecf,efd->ecd", act, wd).reshape(E_local * C, d)
        eout = jnp.concatenate([eout, jnp.zeros((1, d), xl.dtype)], axis=0)
        tok_out = eout[slot] * (flat_w * keep)[:, None]
        out = jnp.zeros((Tl, d), xl.dtype).at[flat_tok].add(tok_out)
        if m.num_shared_experts:
            # shared-expert hidden dim is sharded over "model", so its
            # partial joins the expert partials in ONE psum
            out = out + (jax.nn.silu(xt @ shared["w_gate"])
                         * (xt @ shared["w_up"])) @ shared["w_down"]
        out = jax.lax.psum(out, "model")
        return out.reshape(Bl, Sl, d), aux

    shared = p.get("shared", {"w_gate": jnp.zeros((cfg.d_model, msize),
                                                  x.dtype)})
    dp_spec = dp if len(dp) > 1 else dp[0]
    has_shared = m.num_shared_experts > 0
    shared_specs = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
                    "w_down": P("model", None)} if has_shared else P(None,
                                                                     None)
    out, aux = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None), shared_specs),
        out_specs=(P(dp_spec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
      p.get("shared", shared))
    return out, aux
