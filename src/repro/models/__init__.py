from repro.models import blocks, frontends, lm, moe, rglru, rwkv6
