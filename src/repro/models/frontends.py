"""Modality frontend STUBS (per assignment: `[audio]`/`[vlm]` entries specify
the transformer backbone only; the frontend provides precomputed frame/patch
embeddings). Only the projection into d_model is a real parameter."""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import _dense_init

Params = Dict[str, Any]


def init_frontend(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    assert cfg.frontend is not None
    return {"proj": _dense_init(key, cfg.frontend.feature_dim, cfg.d_model,
                                dtype)}


def apply_vision_prefix(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                        vision_embeds: jnp.ndarray) -> jnp.ndarray:
    """Overwrite the first `prefix_len` positions of the token-embedding
    stream with projected patch embeddings. x: [B, S, d];
    vision_embeds: [B, prefix_len, feature_dim]."""
    vis = vision_embeds.astype(x.dtype) @ p["proj"]
    n = cfg.frontend.prefix_len
    return jnp.concatenate([vis[:, :n], x[:, n:]], axis=1)


def apply_audio_features(cfg: ModelConfig, p: Params,
                         features: jnp.ndarray) -> jnp.ndarray:
    """Project precomputed frames into the model stream.
    features: [B, S, feature_dim] -> [B, S, d]."""
    return features @ p["proj"]


def mrope_positions(cfg: ModelConfig, batch: int, seq: int,
                    offset=0) -> jnp.ndarray:
    """M-RoPE position ids [3, B, S] (Qwen2-VL). The image prefix uses a
    2D (h, w) grid at temporal position 0; text continues all three streams
    from the prefix. For pure text the three streams coincide.
    `offset` is an int or a per-sequence [B] array (decode)."""
    n = cfg.frontend.prefix_len if cfg.frontend else 0
    side = max(1, int(n ** 0.5))
    if isinstance(offset, int):
        offset = jnp.full((batch,), offset, jnp.int32)
    pos = jnp.arange(seq)[None, :] + offset[:, None]        # [B, S]
    t_pos = jnp.where(pos < n, 0, pos - n + 1)
    h_pos = jnp.where(pos < n, pos // side, pos - n + 1)
    w_pos = jnp.where(pos < n, pos % side, pos - n + 1)
    return jnp.stack([t_pos, h_pos, w_pos])                 # [3, B, S]
