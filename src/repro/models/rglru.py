"""RG-LRU recurrence (RecurrentGemma / Griffin) — real-gated linear recurrent
unit with a preceding 1D conv, as in arXiv:2402.19427.

    r_t = sigmoid(x_t W_r)                      (recurrence gate)
    i_t = sigmoid(x_t W_i)                      (input gate)
    a_t = a^(c * r_t)          a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Implemented as an associative scan over the sequence (O(S log S) work,
sub-quadratic memory) for train/prefill, and a single-step update for decode.
The scan is linear in a diagonal state -> parallelizable with
`jax.lax.associative_scan`, which is also how the chunked sequence-parallel
path exchanges boundary states across shards.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import _dense_init
from repro.runtime import hints

Params = Dict[str, Any]

_C = 8.0          # paper's fixed temperature on the recurrence gate
_CONV_K = 4       # temporal conv width (Griffin block)


def init_rglru(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(Lambda) in [0.9, 0.999] (paper init)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "w_x": _dense_init(ks[1], d, w, dtype),       # input projection
        "w_y": _dense_init(ks[2], d, w, dtype),       # gate branch (GeGLU-ish)
        "conv": (jax.random.normal(ks[3], (_CONV_K, w), jnp.float32)
                 / math.sqrt(_CONV_K)).astype(dtype),
        "w_r": _dense_init(ks[4], w, w, dtype),
        "w_i": _dense_init(ks[5], w, w, dtype),
        "lambda": lam.astype(jnp.float32),
        "w_out": _dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _gates(p: Params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a_t (log-space) and gated input. x: [..., w]."""
    r = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(-p["lambda"])   # log sigmoid(Lambda)*c*r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6)) \
        * i * x.astype(jnp.float32)
    return a, gated


def _causal_conv(p: Params, x: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over time. x: [B, S, w]."""
    w = p["conv"]                                     # [K, w]
    if conv_state is None:
        conv_state = jnp.zeros(x.shape[:1] + (_CONV_K - 1, x.shape[-1]),
                               x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)     # [B, S+K-1, w]
    out = sum(xp[:, k:k + x.shape[1]] * w[k] for k in range(_CONV_K))
    new_state = xp[:, -(_CONV_K - 1):]
    return out, new_state


def rglru_scan(p: Params, x: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence recurrence via associative scan. x: [B, S, w] (post-conv).
    Returns (h [B, S, w] float32, h_last [B, w])."""
    a, gated = _gates(p, x)                          # [B, S, w] f32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0)
    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h, h[:, -1]


def rglru_step(p: Params, x_t: jnp.ndarray,
               h_prev: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step. x_t: [B, w] (post-conv), h_prev: [B, w] f32."""
    a, gated = _gates(p, x_t)
    h = a * h_prev + gated
    return h, h


def init_rglru_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    w = cfg.rnn_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_K - 1, w), jnp.float32)}


def apply_rglru(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                state: Optional[Dict[str, jnp.ndarray]] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Griffin recurrent block. x: [B, S, d] -> [B, S, d].

    state None -> full-sequence scan (train/prefill, no state out unless
    provided); state given -> stateful (prefill chunk or S==1 decode).
    """
    B, S, d = x.shape
    u = x @ p["w_x"]                                  # [B, S, w]
    gate = jax.nn.gelu(x @ p["w_y"])
    dp = hints.batch_spec_axes()
    u = hints.constrain(u, dp, None, "model")       # recurrence width-sharded
    gate = hints.constrain(gate, dp, None, "model")
    if state is None:
        conv_in, _ = _causal_conv(p, u, None)
        h, _ = rglru_scan(p, conv_in)
        out = (h.astype(x.dtype) * gate) @ p["w_out"]
        return out, None
    conv_in, new_conv = _causal_conv(p, u, state["conv"].astype(u.dtype))
    if S == 1:
        h_t, h_new = rglru_step(p, conv_in[:, 0], state["h"])
        h = h_t[:, None]
    else:
        h, h_new = rglru_scan(p, conv_in, h0=state["h"])
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h_new, "conv": new_conv.astype(jnp.float32)}
