"""Model assembly: embedding -> scanned block stack -> head, for all ten
assigned architectures, with train (full-sequence), prefill (stateful), and
decode (single-token, cached) paths.

Layer stacking: the block pattern (e.g. RecurrentGemma's
(rglru, rglru, local)) repeats every `period` layers. The stack is scanned
over *periods* — `num_layers // period` iterations of a body holding one
instance of each pattern position — which keeps HLO size O(period) while
supporting heterogeneous stacks. Remainder layers (38 = 12*3 + 2) run
unrolled. Homogeneous models degenerate to the classic scan-over-layers.

Caches ride the scan as per-period xs/ys; each pattern position owns a
kind-specific cache (attention KV / RG-LRU h+conv / RWKV6 state).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (BLOCK_FULL, BLOCK_LOCAL, BLOCK_RGLRU,
                                BLOCK_RWKV6, ModelConfig)
from repro.models import blocks as B
from repro.models import frontends as F
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import rwkv6 as W
from repro.runtime import hints

Params = Dict[str, Any]

# Execution knobs (perf iterations mutate these)
LM_CONFIG = {"seq_parallel_residual": 0}   # 1 -> Korthikanti-style SP


# ==================================================================== init
def _init_layer(cfg: ModelConfig, kind: str, key, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": B.init_norm(cfg, cfg.d_model),
                 "norm2": B.init_norm(cfg, cfg.d_model)}
    if kind in (BLOCK_FULL, BLOCK_LOCAL):
        p["mix"] = B.init_attention(cfg, k1, dtype)
    elif kind == BLOCK_RGLRU:
        p["mix"] = R.init_rglru(cfg, k1, dtype)
    elif kind == BLOCK_RWKV6:
        p["mix"] = W.init_rwkv6(cfg, k1, dtype)
    else:
        raise ValueError(kind)
    if cfg.moe is not None:
        p["ffn"] = M.init_moe(cfg, k2, dtype)
    else:
        p["ffn"] = B.init_mlp(cfg, k2, dtype)
    return p


def _init_period(cfg: ModelConfig, key, dtype) -> Tuple[Params, ...]:
    keys = jax.random.split(key, len(cfg.block_pattern))
    return tuple(_init_layer(cfg, kind, k, dtype)
                 for kind, k in zip(cfg.block_pattern, keys))


def init_model(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    period = len(cfg.block_pattern)
    n_periods, n_tail = divmod(cfg.num_layers, period)
    ks = jax.random.split(key, 6)
    params: Params = {}
    if cfg.frontend is None or cfg.frontend.kind == "vision":
        emb = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                 jnp.float32) * 0.02).astype(dtype)
        params["embed"] = emb
    if cfg.frontend is not None:
        params["frontend"] = F.init_frontend(cfg, ks[1], dtype)
    if n_periods:
        pkeys = jax.random.split(ks[2], n_periods)
        params["scan"] = jax.vmap(
            lambda k: _init_period(cfg, k, dtype))(pkeys)
    if n_tail:
        tkeys = jax.random.split(ks[3], n_tail)
        params["tail"] = [
            _init_layer(cfg, cfg.block_pattern[i % period], tkeys[i], dtype)
            for i in range(n_tail)]
    params["final_norm"] = B.init_norm(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = B._dense_init(ks[4], cfg.d_model, cfg.vocab_size,
                                       dtype)
    return params


# =================================================================== caches
def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Params:
    hd, hkv = cfg.resolved_head_dim, cfg.num_kv_heads
    if kind == BLOCK_FULL:
        return {"k": jnp.zeros((batch, max_len, hkv, hd), dtype),
                "v": jnp.zeros((batch, max_len, hkv, hd), dtype)}
    if kind == BLOCK_LOCAL:
        w = min(cfg.window_size or max_len, max_len)
        return {"k": jnp.zeros((batch, w, hkv, hd), dtype),
                "v": jnp.zeros((batch, w, hkv, hd), dtype)}
    if kind == BLOCK_RGLRU:
        return R.init_rglru_state(cfg, batch)
    if kind == BLOCK_RWKV6:
        return W.init_rwkv6_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    """Decode cache pytree: {"scan": leaves [P, ...], "tail": [...],
    "len": [B]} — `len` is the shared valid-prefix length."""
    period = len(cfg.block_pattern)
    n_periods, n_tail = divmod(cfg.num_layers, period)
    cache: Params = {"len": jnp.zeros((batch,), jnp.int32)}
    if n_periods:
        one = tuple(init_layer_cache(cfg, kind, batch, max_len, dtype)
                    for kind in cfg.block_pattern)
        cache["scan"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one)
    if n_tail:
        cache["tail"] = [init_layer_cache(cfg, cfg.block_pattern[i % period],
                                          batch, max_len, dtype)
                         for i in range(n_tail)]
    return cache


# =================================================================== layers
def _apply_layer(cfg: ModelConfig, kind: str, p: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, cache: Optional[Params],
                 cache_len: Optional[jnp.ndarray], use_kernels: bool,
                 moe_mode: str) -> Tuple[jnp.ndarray, Optional[Params],
                                         jnp.ndarray]:
    if LM_CONFIG["seq_parallel_residual"] and x.shape[1] > 1:
        # sequence-parallel residual stream: norms/elementwise run with S
        # sharded over "model"; XLA all-gathers S at the matmul boundaries
        # and reduce-scatters the outputs (halves activation-collective
        # volume vs all-reduce and shards the residual/norm memory).
        x = hints.constrain(x, hints.batch_spec_axes(), "model", None)
    h = B.apply_norm(cfg, p["norm1"], x)
    new_cache = None
    window = cfg.window_size if kind == BLOCK_LOCAL else 0
    if kind in (BLOCK_FULL, BLOCK_LOCAL):
        if cache is not None:
            if kind == BLOCK_LOCAL and h.shape[1] == 1:
                # decode through the ring-buffered window cache
                out, nc = B.ring_attention_step(
                    cfg, p["mix"], h, positions, cache["k"], cache["v"],
                    cache_len)
            elif kind == BLOCK_LOCAL:
                # windowed prefill; ring-fill the cache with the last W
                # tokens (slot = absolute position mod W)
                out, kv = B.attention(cfg, p["mix"], h, positions,
                                      window=window, use_kernels=use_kernels,
                                      return_kv=True)
                Wn = cache["k"].shape[1]
                S = h.shape[1]
                take = min(Wn, S)
                slots = (jnp.arange(S - take, S)) % Wn
                nc = (cache["k"].at[:, slots].set(
                          kv[0][:, -take:].astype(cache["k"].dtype)),
                      cache["v"].at[:, slots].set(
                          kv[1][:, -take:].astype(cache["v"].dtype)))
            elif h.shape[1] > 1:
                # full-attention prefill: run self-attention (chunked for
                # long S) and bulk-fill the cache prefix — avoids the
                # [S, T_max] masked-cache path entirely.
                out, kv = B.attention(cfg, p["mix"], h, positions,
                                      window=window, use_kernels=use_kernels,
                                      return_kv=True)
                S = h.shape[1]
                nc = (cache["k"].at[:, :S].set(kv[0].astype(cache["k"].dtype)),
                      cache["v"].at[:, :S].set(kv[1].astype(cache["v"].dtype)))
            else:
                out, nc = B.attention(cfg, p["mix"], h, positions,
                                      kv_cache=(cache["k"], cache["v"]),
                                      cache_len=cache_len,
                                      window=window, use_kernels=use_kernels)
            new_cache = {"k": nc[0], "v": nc[1]}
        else:
            out, _ = B.attention(cfg, p["mix"], h, positions, window=window,
                                 use_kernels=use_kernels)
        aux = jnp.zeros((), jnp.float32)
    elif kind == BLOCK_RGLRU:
        out, new_cache = R.apply_rglru(cfg, p["mix"], h, cache)
        aux = jnp.zeros((), jnp.float32)
    else:  # rwkv6
        out, new_cache = W.apply_rwkv6(cfg, p["mix"], h, cache)
        aux = jnp.zeros((), jnp.float32)
    x = x + out
    h2 = B.apply_norm(cfg, p["norm2"], x)
    if cfg.moe is not None:
        ffn_out, aux = M.apply_moe(cfg, p["ffn"], h2, mode=moe_mode)
    else:
        ffn_out = B.apply_mlp(cfg, p["ffn"], h2)
    return x + ffn_out, new_cache, aux


# ================================================================== forward
def _remat_wrap(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def forward_blocks(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, cache: Optional[Params] = None,
                   use_kernels: bool = False, moe_mode: str = "capacity",
                   remat: str = "none"
                   ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    period = len(cfg.block_pattern)
    n_periods, n_tail = divmod(cfg.num_layers, period)
    cache_len = cache["len"] if cache is not None else None
    new_cache: Params = {} if cache is not None else None
    aux_total = jnp.zeros((), jnp.float32)

    def period_body(carry, xs):
        xc, aux = carry
        pparams, pcache = xs
        ncaches = []
        for j, kind in enumerate(cfg.block_pattern):
            c_j = None if pcache is None else pcache[j]
            xc, nc, a = _apply_layer(cfg, kind, pparams[j], xc, positions,
                                     c_j, cache_len, use_kernels, moe_mode)
            ncaches.append(nc)
            aux = aux + a
        out_caches = tuple(ncaches) if pcache is not None else None
        return (xc, aux), out_caches

    if n_periods:
        body = _remat_wrap(period_body, remat)
        scan_cache = cache["scan"] if cache is not None else None
        (x, aux_total), updated = jax.lax.scan(
            body, (x, aux_total),
            (params["scan"], scan_cache))
        if cache is not None:
            new_cache["scan"] = updated
    if n_tail:
        tail_caches = []
        for i in range(n_tail):
            kind = cfg.block_pattern[i % period]
            c_i = cache["tail"][i] if cache is not None else None
            x, nc, a = _apply_layer(cfg, kind, params["tail"][i], x,
                                    positions, c_i, cache_len, use_kernels,
                                    moe_mode)
            aux_total = aux_total + a
            tail_caches.append(nc)
        if cache is not None:
            new_cache["tail"] = tail_caches
    return x, new_cache, aux_total


def embed_inputs(cfg: ModelConfig, params: Params, inputs: Dict[str, Any],
                 dtype=jnp.bfloat16) -> jnp.ndarray:
    """tokens/features -> [B, S, d] stream."""
    if cfg.frontend is not None and cfg.frontend.kind == "audio":
        return F.apply_audio_features(
            cfg, params["frontend"], inputs["features"].astype(dtype))
    x = params["embed"].astype(dtype)[inputs["tokens"]]
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        x = F.apply_vision_prefix(cfg, params["frontend"], x,
                                  inputs["vision_embeds"])
    return x


def positions_for(cfg: ModelConfig, batch: int, seq: int,
                  offset=0) -> jnp.ndarray:
    if cfg.mrope_sections:
        return F.mrope_positions(cfg, batch, seq, offset)
    pos = jnp.arange(seq)[None, :] + (
        offset if isinstance(offset, int) else offset[:, None])
    return jnp.broadcast_to(pos, (batch, seq)) if pos.shape[0] == 1 else pos


def _head_logits(cfg: ModelConfig, params: Params,
                 x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x @ params["embed"].astype(x.dtype).T
    return x @ params["head"]


def chunked_xent(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 labels: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy without materializing full [B, S, V] logits: scan over
    sequence chunks with rematerialization (the logits are recomputed in the
    backward pass chunk by chunk)."""
    Bsz, S, d = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    xc = x[:, :n * chunk].reshape(Bsz, n, chunk, d).swapaxes(0, 1)
    lc = labels[:, :n * chunk].reshape(Bsz, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(carry, xs):
        xm, lm = xs
        logits = _head_logits(cfg, params, xm).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lm[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (Bsz * n * chunk)


def cast_params_for_compute(params: Params, dtype=jnp.bfloat16) -> Params:
    """Cast >=2D float32 weights to the compute dtype (master copies stay in
    the optimizer); 1D scales/biases and integer leaves keep their dtype."""
    def cast(t):
        if isinstance(t, jnp.ndarray) and t.dtype == jnp.float32 and t.ndim >= 2:
            return t.astype(dtype)
        return t
    return jax.tree.map(cast, params)


# ============================================================== entrypoints
def train_loss(cfg: ModelConfig, params: Params, inputs: Dict[str, Any],
               use_kernels: bool = False, moe_mode: str = "capacity",
               remat: str = "selective",
               dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full-sequence LM (or masked-frame) loss."""
    params = cast_params_for_compute(params, dtype)
    x = embed_inputs(cfg, params, inputs, dtype)
    Bsz, S = x.shape[:2]
    positions = positions_for(cfg, Bsz, S)
    x, _, aux = forward_blocks(cfg, params, x, positions, None,
                               use_kernels, moe_mode, remat)
    x = B.apply_norm(cfg, params["final_norm"], x)
    loss = chunked_xent(cfg, params, x, inputs["labels"])
    if cfg.moe is not None:
        loss = loss + cfg.moe.load_balance_loss_weight * aux / cfg.num_layers
    return loss, {"aux_loss": aux}


def prefill(cfg: ModelConfig, params: Params, inputs: Dict[str, Any],
            cache: Params, use_kernels: bool = False,
            moe_mode: str = "capacity",
            dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Params]:
    """Encoder forward / decoder prefill: returns last-position logits and a
    filled cache (for decoders)."""
    params = cast_params_for_compute(params, dtype)
    x = embed_inputs(cfg, params, inputs, dtype)
    Bsz, S = x.shape[:2]
    positions = positions_for(cfg, Bsz, S)
    x, new_cache, _ = forward_blocks(cfg, params, x, positions,
                                     cache if cfg.is_decoder else None,
                                     use_kernels, moe_mode)
    x = B.apply_norm(cfg, params["final_norm"], x)
    logits = _head_logits(cfg, params, x[:, -1:])
    if new_cache is not None:
        new_cache["len"] = cache["len"] + S
    return logits, new_cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                cache: Params, use_kernels: bool = False,
                moe_mode: str = "capacity",
                dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Params]:
    """One decode step: tokens [B, 1] + cache -> logits [B, 1, V] + cache."""
    params = cast_params_for_compute(params, dtype)
    x = params["embed"][tokens]
    Bsz = x.shape[0]
    positions = positions_for(cfg, Bsz, 1, offset=cache["len"])
    x, new_cache, _ = forward_blocks(cfg, params, x, positions, cache,
                                     use_kernels, moe_mode)
    x = B.apply_norm(cfg, params["final_norm"], x)
    logits = _head_logits(cfg, params, x)
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache
