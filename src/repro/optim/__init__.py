from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
