"""Optimizer substrate: AdamW with cosine schedule, global-norm clipping,
ZeRO-1 state sharding specs, and int8 error-feedback gradient compression
for the cross-pod reduction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.learning_rate * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.minimum(warm, cfg.learning_rate * cos)


def init_state(params: Params, moment_dtype=jnp.float32) -> Dict[str, Any]:
    """moment_dtype=bf16 halves optimizer memory (needed for the 1T-param
    configs); the update math still runs in f32."""
    zeros = lambda t: jnp.zeros_like(t, dtype=moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jnp.ndarray]:
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                     grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def apply_updates(cfg: AdamWConfig, params: Params, grads: Params,
                  state: Dict[str, Any]) -> Tuple[Params, Dict[str, Any],
                                                  Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"]
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        mdt = m.dtype
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------- gradient compression
def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_cross_pod_mean(grads: Params, axis_name: str = "pod",
                              error: Optional[Params] = None
                              ) -> Tuple[Params, Params]:
    """int8 error-feedback all-reduce over the pod axis (inside shard_map):
    quantize (grad + residual), psum the int8 payload (4x fewer inter-pod
    bytes), dequantize, keep the quantization error as the next residual."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        mean = (total.astype(jnp.float32) * scale) / n.astype(jnp.float32)
        new_e = g32 - dequantize_int8(q, scale)
        return mean.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
