"""Data substrate: deterministic synthetic token pipeline with asynchronous
host-side prefetch — the runtime-level instance of the paper's pattern
(issue the next batch's "aload" while the step computes).

`input_specs` is the dry-run contract: jax.ShapeDtypeStruct stand-ins for
every model input of an (arch x shape) cell, shardable and allocation-free.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (KIND_DECODE, KIND_PREFILL, KIND_TRAIN,
                                ModelConfig, ShapeConfig)


# ------------------------------------------------------------- dry-run specs
def input_specs(model: ModelConfig, shape: ShapeConfig,
                sharding_fn=None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct for every model input (no allocation).

    sharding_fn(logical_name) -> Sharding | None attaches shardings for the
    dry-run lowering.
    """
    B, S = shape.global_batch, shape.seq_len

    def sds(shp, dtype, name):
        sh = sharding_fn(name) if sharding_fn else None
        return jax.ShapeDtypeStruct(shp, dtype, sharding=sh)

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == KIND_TRAIN:
        if model.frontend is not None and model.frontend.kind == "audio":
            specs["features"] = sds((B, S, model.frontend.feature_dim),
                                    jnp.bfloat16, "activations")
        else:
            specs["tokens"] = sds((B, S), jnp.int32, "tokens")
        specs["labels"] = sds((B, S), jnp.int32, "tokens")
        if model.frontend is not None and model.frontend.kind == "vision":
            specs["vision_embeds"] = sds(
                (B, model.frontend.prefix_len, model.frontend.feature_dim),
                jnp.bfloat16, "activations")
    elif shape.kind == KIND_PREFILL:
        if model.frontend is not None and model.frontend.kind == "audio":
            specs["features"] = sds((B, S, model.frontend.feature_dim),
                                    jnp.bfloat16, "activations")
        else:
            specs["tokens"] = sds((B, S), jnp.int32, "tokens")
        if model.frontend is not None and model.frontend.kind == "vision":
            specs["vision_embeds"] = sds(
                (B, model.frontend.prefix_len, model.frontend.feature_dim),
                jnp.bfloat16, "activations")
    else:  # decode: one new token per sequence; the KV/state cache rides
        specs["tokens"] = sds((B, 1), jnp.int32, "tokens")
    return specs


# ------------------------------------------------------- synthetic batches
def synthetic_batch(model: ModelConfig, shape: ShapeConfig, step: int,
                    seed: int = 0,
                    batch_override: Optional[int] = None) -> Dict[str, Any]:
    """Deterministic batch for (step, seed) — restart-safe: a resumed run
    sees exactly the data it would have seen."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    batch: Dict[str, Any] = {}
    if shape.kind == KIND_DECODE:
        batch["tokens"] = rng.integers(0, model.vocab_size, (B, 1),
                                       dtype=np.int32)
        return batch
    if model.frontend is not None and model.frontend.kind == "audio":
        batch["features"] = rng.standard_normal(
            (B, S, model.frontend.feature_dim)).astype(np.float32)
    else:
        batch["tokens"] = rng.integers(0, model.vocab_size, (B, S),
                                       dtype=np.int32)
    if shape.kind == KIND_TRAIN:
        batch["labels"] = rng.integers(0, model.vocab_size, (B, S),
                                       dtype=np.int32)
    if model.frontend is not None and model.frontend.kind == "vision":
        batch["vision_embeds"] = rng.standard_normal(
            (B, model.frontend.prefix_len,
             model.frontend.feature_dim)).astype(np.float32)
    return batch


class PrefetchingLoader:
    """Asynchronous host prefetch: a producer thread keeps `depth` batches
    ready (device_put'ed when a sharding is given) while the train step runs.
    This is `aload` at the pipeline level: issue ahead, consume on demand."""

    def __init__(self, model: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 depth: int = 2, start_step: int = 0, sharding=None,
                 batch_override: Optional[int] = None):
        self.model, self.shape, self.seed = model, shape, seed
        self.sharding = sharding
        self.batch_override = batch_override
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        step = self._step
        while not self._stop.is_set():
            batch = synthetic_batch(self.model, self.shape, step, self.seed,
                                    self.batch_override)
            if self.sharding is not None:
                batch = {k: jax.device_put(v, self.sharding.get(k))
                         for k, v in batch.items()}
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
