from repro.data.pipeline import PrefetchingLoader, input_specs, synthetic_batch
