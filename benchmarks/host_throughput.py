"""Host-throughput archive: best-of-N driver throughput for the epoch-fused
command plane, written to ``results/host_throughput.json`` (uploaded by the
nightly job).

This is the *host* speed story — wall-clock requests retired per second
through the full scheduler + batched-engine + far-model stack — not a model
result: every configuration measured here is bit-identical in model terms
(trace, stats, RNG bitstreams; tests/test_epoch_fusion.py). Each point is
the best of ``--reps`` runs because small-numpy driver loops are noisy
(±20% on a loaded machine); best-of isolates the code's floor from the
machine's weather.

Usage: PYTHONPATH=src python -m benchmarks.host_throughput \
           [--out results/host_throughput.json] [--reps 5]
"""
from __future__ import annotations

import json
import sys
import time

# PR 6 baseline (commit 8a0da7e, per-command BatchScheduler — the last
# pre-fusion command plane), measured from a worktree of that commit on the
# machine that produced this archive, best-of-5 at identical workload
# shapes. PR 6's own archived nightly put GUPS_sched_vector at 363,389
# req/s; the same code measures faster on this box, so ratios below use
# the same-machine numbers (the conservative denominator).
PR6_BASELINE = {
    "GUPS_vector_req_per_s": 420_088.0,
    "serve_vector_req_per_s": 24_354.0,
    "GUPS_vector_req_per_s_archived_nightly": 363_389.0,
}


def _best(fn, reps: int):
    best = None
    for _ in range(reps):
        out = fn()
        if best is None or out[0] > best[0]:
            best = out
    return best


def _gups(scheduler: str, vector: bool = True):
    from benchmarks.kernel_micro import _drive_workload_port
    rps, st = _drive_workload_port("GUPS", vector=vector, updates=65_536,
                                   scheduler=scheduler)
    return rps, st


def _serve(scheduler: str):
    """Serving driver throughput: far-memory requests per wall-second for a
    scaled-up paged-KV run (open-loop Poisson arrivals, mixed tiers). Note
    epoch fusion is structurally weak here — arrivals trickle in, so epochs
    carry only a handful of rows (see rows_per_entry in the archive)."""
    from repro.amu import AmuConfig, AmuSession
    from repro.core.serving import serve_regions

    cfg = AmuConfig(engine="batched", scheduler=scheduler, vector=True,
                    far=serve_regions(requests=1024), verify=False)
    s = AmuSession(cfg)
    s.prepare("paged_kv_serve", requests=1024, coroutines=64)
    t0 = time.perf_counter()
    st = s.execute()
    return st.requests / (time.perf_counter() - t0), st


def measure(reps: int = 5) -> dict:
    points = {}
    for label, fn in (
            ("GUPS_scalar_yield", lambda: _gups("auto", vector=False)),
            ("GUPS_vector_percmd", lambda: _gups("batched")),
            ("GUPS_vector_fused", lambda: _gups("auto")),
            ("serve_vector_percmd", lambda: _serve("batched")),
            ("serve_vector_fused", lambda: _serve("auto"))):
        rps, st = _best(fn, reps)
        points[label] = {
            "req_per_s": round(rps),
            "engine_entries": st.engine_entries,
            "rows_per_entry": round(st.rows_per_entry, 1),
            "us_per_entry": round(st.us_per_entry, 1),
        }
    return {
        "note": "host driver throughput, best of %d reps per point; "
                "model-identical across all points (epoch fusion is a "
                "host-speed refactor, pinned by tests/test_epoch_fusion.py)"
                % reps,
        "points": points,
        "pr6_baseline": PR6_BASELINE,
        "speedup_vs_pr6": {
            "GUPS_vector_fused":
                round(points["GUPS_vector_fused"]["req_per_s"]
                      / PR6_BASELINE["GUPS_vector_req_per_s"], 2),
            "GUPS_vector_fused_vs_archived_nightly":
                round(points["GUPS_vector_fused"]["req_per_s"]
                      / PR6_BASELINE[
                          "GUPS_vector_req_per_s_archived_nightly"], 2),
            "serve_vector_fused":
                round(points["serve_vector_fused"]["req_per_s"]
                      / PR6_BASELINE["serve_vector_req_per_s"], 2),
            "serve_vector_percmd":
                round(points["serve_vector_percmd"]["req_per_s"]
                      / PR6_BASELINE["serve_vector_req_per_s"], 2),
        },
        "entry_collapse": {
            "GUPS": round(points["GUPS_vector_percmd"]["engine_entries"]
                          / points["GUPS_vector_fused"]["engine_entries"], 1),
            "serve": round(points["serve_vector_percmd"]["engine_entries"]
                           / points["serve_vector_fused"]["engine_entries"],
                           1),
        },
    }


def main() -> None:
    args = sys.argv[1:]
    out_path = "results/host_throughput.json"
    reps = 5
    if "--out" in args:
        i = args.index("--out")
        out_path = args[i + 1]
    if "--reps" in args:
        i = args.index("--reps")
        reps = int(args[i + 1])
    archive = measure(reps=reps)
    with open(out_path, "w") as f:
        json.dump(archive, f, indent=1)
        f.write("\n")
    print(f"# wrote {out_path}", file=sys.stderr)
    for label, p in archive["points"].items():
        print(f"{label}: {p['req_per_s']} req/s, {p['engine_entries']} "
              f"entries, {p['rows_per_entry']} rows/entry")
    print(f"speedup_vs_pr6: {archive['speedup_vs_pr6']}")
    print(f"entry_collapse: {archive['entry_collapse']}")


if __name__ == "__main__":
    main()
