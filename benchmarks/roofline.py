"""Roofline table generator: reads the dry-run JSONL (produced by
``python -m repro.launch.dryrun --out results/dryrun.jsonl``) and prints the
per-cell three-term roofline with the dominant bottleneck.

Run the dry-run first; this module only formats/derives. `--markdown` emits
the EXPERIMENTS.md table.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

Row = Tuple[str, float, str]

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def load(path: str = DEFAULT_PATH) -> List[Dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    seen = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r.get("arch"), r.get("shape"), r.get("multi_pod"))
            seen[key] = r              # keep the latest rerun of a cell
    return list(seen.values())


def roofline_rows(path: str = DEFAULT_PATH) -> List[Row]:
    rows: List[Row] = []
    for r in load(path):
        tag = f"roofline/{r['arch']}/{r['shape']}/" \
              f"{'pod2' if r.get('multi_pod') else 'pod1'}"
        if r.get("skipped"):
            rows.append((tag, 0.0, f"skipped:{r['reason']}"))
            continue
        if "error" in r:
            rows.append((tag, 0.0, f"error:{r['error'][:80]}"))
            continue
        t = r["terms"]
        step_us = max(t.values()) * 1e6
        rows.append((tag, step_us,
                     f"compute={t['compute_s']:.3f}s,"
                     f"memory={t['memory_s']:.3f}s,"
                     f"collective={t['collective_s']:.3f}s,"
                     f"bottleneck={r['bottleneck'].replace('_s', '')},"
                     f"useful={r['useful_flops_ratio']:.2f},"
                     f"peak_gb={r['mem']['peak_gb']:.1f}"))
    return rows


def markdown_table(path: str = DEFAULT_PATH, multi_pod: bool = False) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | useful flops | peak GB/chip | MFU bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(load(path), key=lambda x: (x["arch"], x["shape"])):
        if r.get("multi_pod", False) != multi_pod:
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped: {r['reason']} | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        t = r["terms"]
        total = max(sum(t.values()), 1e-12)
        mfu = (r["model_flops_total"] / r["chips"] / 197e12) / total
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{r['bottleneck'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['mem']['peak_gb']:.1f} | {mfu:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DEFAULT_PATH)
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    if args.markdown:
        print(markdown_table(args.path, args.multi_pod))
    else:
        for name, us, derived in roofline_rows(args.path):
            print(f"{name},{us:.1f},{derived}")
