"""One function per paper table/figure, driving the calibrated model in
`repro.core.simulator`. Each returns rows of (name, value, derived) and
prints `name,us_per_call,derived` CSV via benchmarks.run."""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.amu import (REGISTRY, AmuConfig, AmuSession, BimodalTail,
                       FaultModel, LinkFlap, LognormalLatency, RetryPolicy,
                       far_config, far_region)
from repro.core import simulator as sim
from repro.core.simulator import PowerModel

LATS = [0.1, 0.2, 0.5, 1.0, 2.0, 5.0]
# throughput-normalized sweeps skip request-level workloads (their cycle
# counts include open-loop arrival idle); serving has its own sweep below
WORKLOADS = [n for n, d in REGISTRY.items() if not d.request_level]
Row = Tuple[str, float, str]

# The AmuConfig behind every AMU data point of the sweep. The default drives
# the batched engine + batch-stepped scheduler, which makes the full
# 4-config x workload x latency grid tractable on CPU ("scalar" is the
# per-event oracle; the engines are trace-identical under a fixed scheduler
# — tests/test_batched_engine.py — and the batch-stepped scheduler's
# different interleaving shifts timing stats ~1%, so archived sweeps record
# which config produced them). `benchmarks.run --engine/--vector` derive
# onto this. vector=True runs the AloadVec/AstoreVec (and software-
# pipelined chase) ports: trace-equivalent in memory effects, several times
# faster on the host, but MODELING the vector-AMI software configuration
# (one amortized issue per request vector) — a faster machine point than
# the paper's scalar coroutine port. Record residuals vs the paper from
# scalar-port sweeps; archive --vector sweeps as the vector-AMI variant.
AMU = AmuConfig(engine="batched")


def _run(wl: str, config: str, latency_us: float, **kw) -> Dict[str, float]:
    if config.startswith("amu"):
        kw.setdefault("amu", AMU)
    return sim.run(wl, config, latency_us, **kw)


def fig2_slowdown() -> List[Row]:
    """Fig 2: baseline slowdown vs far-memory latency (normalized to 0.1us)."""
    rows = []
    for wl in WORKLOADS:
        base = [_run(wl, "baseline", L)["us"] for L in LATS]
        for L, t in zip(LATS, base):
            rows.append((f"fig2/{wl}/lat{L}", t,
                         f"slowdown={t / base[0]:.2f}x"))
    return rows


def fig8_exec_time() -> List[Row]:
    """Fig 8: normalized execution time, 4 configs x workloads x latencies."""
    rows = []
    for wl in WORKLOADS:
        b0 = _run(wl, "baseline", 0.1)["us"]
        for config in ("baseline", "cxl-ideal", "amu", "amu-dma"):
            for L in (0.1, 0.5, 1.0, 5.0):
                out = _run(wl, config, L, verify=False) \
                    if config.startswith("amu") else _run(wl, config, L)
                rows.append((f"fig8/{wl}/{config}/lat{L}", out["us"],
                             f"norm={out['us'] / b0:.3f}"))
    return rows


def fig9_mlp() -> List[Row]:
    """Fig 9: average in-flight far-memory requests (MLP)."""
    rows = []
    for wl in WORKLOADS:
        for config in ("baseline", "amu"):
            for L in (0.5, 1.0, 5.0):
                out = _run(wl, config, L, verify=False) \
                    if config == "amu" else _run(wl, config, L)
                rows.append((f"fig9/{wl}/{config}/lat{L}", out["us"],
                             f"mlp={out['mlp']:.1f}"))
    return rows


def fig10_ipc() -> List[Row]:
    """Fig 10: IPC — AMI retires instead of stalling in the ROB."""
    rows = []
    for wl in WORKLOADS:
        for config in ("baseline", "amu"):
            for L in (0.5, 1.0, 5.0):
                out = _run(wl, config, L, verify=False) \
                    if config == "amu" else _run(wl, config, L)
                rows.append((f"fig10/{wl}/{config}/lat{L}", out["us"],
                             f"ipc={out['ipc']:.2f}"))
    return rows


def fig11_power() -> List[Row]:
    """Fig 11: power normalized to baseline@0.1us (McPAT-style model)."""
    pm = PowerModel()
    rows = []
    for wl in WORKLOADS:
        b0 = _run(wl, "baseline", 0.1)
        p0 = pm.power(b0)
        for L in (0.5, 1.0, 5.0):
            a = _run(wl, "amu", L, verify=False)
            spm_touches = a["requests"] * 2.0       # AMART + list upkeep
            rows.append((f"fig11/{wl}/amu/lat{L}", a["us"],
                         f"power_norm={pm.power(a, spm_touches) / p0:.2f}"))
    return rows


def table4_prefetch() -> List[Row]:
    """Table 4: baseline vs group software prefetch (best/specific group
    sizes) vs AMU vs AMU-LLVM, normalized to baseline@0.1us."""
    rows = []
    groups = (2, 8, 16, 32, 64, 128)
    for wl in ("GUPS", "HJ", "STREAM"):
        spec = REGISTRY[wl]
        units = spec.build(0).units
        b0 = _run(wl, "baseline", 0.1)["us"]
        for L in LATS:
            base = _run(wl, "baseline", L)["us"]
            rows.append((f"table4/{wl}/baseline/lat{L}", base,
                         f"norm={base / b0:.2f}"))
            pf = {g: sim.simulate_group_prefetch(
                spec.profile, units, L, g)["cycles"] / 3e3 for g in groups}
            g_best = min(pf, key=pf.get)
            rows.append((f"table4/{wl}/pf_best/lat{L}", pf[g_best],
                         f"norm={pf[g_best] / b0:.2f},group={g_best}"))
            amu = _run(wl, "amu", L, verify=False)["us"]
            rows.append((f"table4/{wl}/amu/lat{L}", amu,
                         f"norm={amu / b0:.2f}"))
            llvm = _run(wl, "amu-llvm", L, verify=False)["us"]
            rows.append((f"table4/{wl}/amu_llvm/lat{L}", llvm,
                         f"norm={llvm / b0:.2f}"))
    return rows


def fig3_group_sensitivity() -> List[Row]:
    """Fig 3: GP-GUPS performance vs group size across hardware scales —
    the best group size shifts with resources/latency (prefetch fragility)."""
    rows = []
    spec = REGISTRY["GUPS"]
    units = spec.build(0).units
    for core_name, core in (("cxl_ideal", sim.CXL_IDEAL_CORE),
                            ("x2", sim.CoreConfig(mshr=512, rob=1024,
                                                  lsq=384)),):
        for L in (0.5, 2.0):
            for g in (2, 8, 32, 128):
                out = sim.simulate_group_prefetch(spec.profile, units, L, g,
                                                  core=core)
                rows.append((f"fig3/GUPS/{core_name}/lat{L}/group{g}",
                             out["cycles"] / 3e3,
                             f"mlp={out['mlp']:.1f}"))
    return rows


def tail_latency() -> List[Row]:
    """Tail-latency sweep (heterogeneous far-memory scenarios): GUPS + LL
    at a fixed 1 µs *base* far latency across increasing p99/p50 tail
    ratios — lognormal (network-path variability; mean-preserving, so the
    base is the mean multiplier and the median sits at exp(-σ²/2)) and
    bimodal (retransmit / congestion spikes; the base is the p50) draws —
    plus a mixed-tier GUPS run (local-DRAM + 1 µs CXL + 5 µs cross-switch
    regions, bimodal tail on the switch tier) with per-region request/MLP
    stats. The paper's latency-adaptation claim, on the variability axis:
    AMU throughput should degrade with the *mean* of the draw, not its
    tail ratio, because done-times are known at issue and completions
    dispatch out of order."""
    rows: List[Row] = []
    dists = [
        ("det", None),
        ("lognormal_s0.5", LognormalLatency(0.5)),
        ("lognormal_s1.0", LognormalLatency(1.0)),
        ("bimodal_p5_x8", BimodalTail(0.05, 8.0)),
        ("bimodal_p5_x32", BimodalTail(0.05, 32.0)),
    ]
    # characterize each distribution ONCE, from its own fresh stream, so
    # identical distributions report identical stats across workloads
    shape: Dict[str, Tuple[float, float]] = {"det": (1.0, 1.0)}
    for name, dist in dists:
        if dist is not None:
            draws = dist.draw(np.random.default_rng(0), 200_000)
            shape[name] = (float(np.quantile(draws, 0.99)
                                 / np.quantile(draws, 0.5)),
                           float(np.mean(draws)))
    for wl in ("GUPS", "LL"):
        det_us = None
        for name, dist in dists:
            cfg = AMU.derive(far=far_config(1.0, distribution=dist))
            with AmuSession(cfg.derive(verify=False)) as s:
                out = s.run(wl)
            ratio, mean = shape[name]
            det_us = det_us if det_us is not None else out.us
            rows.append((f"tail/{wl}/{name}", out.us,
                         f"p99_over_p50={ratio:.1f},mean_mult={mean:.2f},"
                         f"mlp={out.mlp:.1f},"
                         f"slowdown_vs_det={out.us / det_us:.2f}x"))
    # mixed-tier GUPS: a third of the table in each of local-DRAM / CXL /
    # cross-switch (the switch tier with a bimodal congestion tail), the
    # two far tiers contending on one shared channel
    table_words = 8192
    third = (table_words * 8 // 3) // 8 * 8
    regions = [
        far_region("local", 0, third, 0.08),
        far_region("cxl", third, third, 1.0, link="switch"),
        far_region("xswitch", 2 * third, table_words * 8 - 2 * third, 5.0,
                   distribution=BimodalTail(0.05, 8.0), link="switch"),
    ]
    with AmuSession(AMU.derive(far=regions)) as s:
        out = s.run("GUPS", table_words=table_words, distinct=True)
    assert out.verified
    rows.append(("tail/GUPS/mixed_tier", out.us,
                 f"mlp={out.mlp:.1f},requests={out.requests}"))
    for rname, rstats in out.regions.items():
        rows.append((f"tail/GUPS/mixed_tier/{rname}", out.us,
                     f"requests={rstats['requests']},"
                     f"mlp={rstats['mlp']:.1f},"
                     f"lat_cycles={rstats['latency_cycles']:.0f},"
                     f"link={rstats['link']}"))
    # the vector-machine points (AloadVec GUPS port) on the same axes: a
    # tail subset plus the mixed-tier scenario, so the archived sweep
    # carries both machine configurations (ROADMAP carried minor)
    vec = AMU.derive(vector=True)
    det_us = None
    for name, dist in dists:
        if name not in ("det", "lognormal_s1.0", "bimodal_p5_x32"):
            continue
        cfg = vec.derive(far=far_config(1.0, distribution=dist))
        with AmuSession(cfg.derive(verify=False)) as s:
            out = s.run("GUPS")
        det_us = det_us if det_us is not None else out.us
        rows.append((f"tail/GUPS/{name}/vector", out.us,
                     f"mlp={out.mlp:.1f},"
                     f"slowdown_vs_det={out.us / det_us:.2f}x"))
    with AmuSession(vec.derive(far=regions)) as s:
        out = s.run("GUPS", table_words=table_words, distinct=True)
    assert out.verified
    rows.append(("tail/GUPS/mixed_tier_vector", out.us,
                 f"mlp={out.mlp:.1f},requests={out.requests}"))
    return rows


def serve_latency(smoke: bool = False) -> List[Row]:
    """Paged-KV serving sweep: per-request completion-latency percentiles
    under open-loop arrivals (Poisson + bursty diurnal), mixed local / CXL /
    cross-switch page tiers, for three data planes — the synchronous
    page-fault baseline (one blocking fetch per page, MLP ~= 1), the
    scalar-coroutine AMI plane, and the vector-AMI plane (one AloadVec
    gather per request). ``ami_vs_sync`` on the AMI rows is the
    mean-latency speedup over the page-fault baseline — the number
    comparable to "A Tale of Two Paths". Smoke mode shrinks the scenario
    and runs Poisson only (the CI gate floors ami_vs_sync)."""
    from repro.core.serving import serve_regions

    rows: List[Row] = []
    kw = dict(requests=64, coroutines=16) if smoke else {}
    regions = serve_regions(**({"requests": 64} if smoke else {}))
    base = AMU.derive(far=regions)
    for arrival in (("poisson",) if smoke else ("poisson", "bursty")):
        with AmuSession(base) as s:
            sync = s.run("paged_kv_serve", data_plane="sync",
                         arrival=arrival, **kw)
        assert sync.verified
        rows.append((f"serve/{arrival}/sync", sync.us,
                     f"p50={sync.req_p50_us:.1f},p99={sync.req_p99_us:.1f},"
                     f"p999={sync.req_p999_us:.1f},mlp={sync.mlp:.2f}"))
        # both machine points always, independent of the global --vector
        for label, cfg in (("ami", base.derive(vector=False)),
                           ("ami_vector", base.derive(vector=True))):
            with AmuSession(cfg) as s:
                out = s.run("paged_kv_serve", arrival=arrival, **kw)
            assert out.verified
            rows.append((
                f"serve/{arrival}/{label}", out.us,
                f"p50={out.req_p50_us:.1f},p99={out.req_p99_us:.1f},"
                f"p999={out.req_p999_us:.1f},mlp={out.mlp:.2f},"
                f"ami_vs_sync={sync.req_mean_us / out.req_mean_us:.2f}x,"
                f"entries={out.engine_entries},"
                f"rows_per_entry={out.rows_per_entry:.1f},"
                f"us_per_entry={out.us_per_entry:.1f}"))
            if label == "ami":
                for rname, rstats in out.regions.items():
                    rows.append((f"serve/{arrival}/ami/{rname}", out.us,
                                 f"requests={rstats['requests']},"
                                 f"mlp={rstats['mlp']:.1f},"
                                 f"link={rstats['link']}"))
    return rows


def fault_tolerance(smoke: bool = False) -> List[Row]:
    """Fault-injection sweep: goodput and tail latency vs fault rate, retry
    policy on/off — the headline curves for the fault plane.

    GUPS runs against a faulted fabric region (seeded per-request error
    draws, failover to a slower backup tier) across error rates;
    ``vs_clean`` is the slowdown against the same config at rate 0 and
    ``goodput_rps`` the availability-weighted request rate. Serving
    (`paged_kv_serve`) takes the faults on its cross-switch tier (failover
    to CXL) and adds mid-run link-outage windows of increasing width —
    p999 and availability through an outage are the "serving millions of
    users" numbers. Smoke mode shrinks to the CI gate: GUPS at 1% error
    with retries must stay within 1.5x of fault-free, serving
    availability >= 0.99 (floors enforced by benchmarks.run --smoke)."""
    from repro.core.serving import serve_regions

    rows: List[Row] = []
    rp = RetryPolicy(max_retries=3, backoff=300.0)
    size = 1 << 22                   # covers the GUPS table; backup above it

    def gups_regions(rate: float) -> List:
        fm = FaultModel(error_prob=rate) if rate else None
        return [far_region("fabric", 0, size, 1.0, faults=fm,
                           failover="backup" if rate else None),
                far_region("backup", size, size, 3.0)]

    # --- GUPS: error-rate sweep, retry on/off (verify off: with retries
    # off, failed loads legitimately leave stale data behind)
    rates = [0.0, 0.01] if smoke else [0.0, 0.005, 0.01, 0.02, 0.05]
    gups_kw = dict(table_words=8192, distinct=True) if smoke else {}
    clean_us: Dict[str, float] = {}
    for rate in rates:
        for tag, retry in (("retry_off", None), ("retry_on", rp)):
            cfg = AMU.derive(far=gups_regions(rate), retry=retry,
                             verify=False)
            with AmuSession(cfg) as s:
                out = s.run("GUPS", **gups_kw)
            if rate == 0.0:
                clean_us[tag] = out.us
            goodput = out.requests * out.availability / out.us
            rows.append((
                f"faults/GUPS/err{rate}/{tag}", out.us,
                f"vs_clean={out.us / clean_us[tag]:.2f}x,"
                f"avail={out.availability:.4f},"
                f"faults={out.faults_injected},retries={out.retries},"
                f"failovers={out.failovers},goodput_rps={goodput:.4f},"
                f"mlp={out.mlp:.1f}"))

    # --- serving: faults on the cross-switch tier, failover to CXL
    serve_kw = dict(requests=64, coroutines=16) if smoke else {}
    size_kw = {"requests": 64} if smoke else {}
    serve_rates = [0.01] if smoke else [0.01, 0.05]
    for rate in serve_rates:
        regs = serve_regions(faults=FaultModel(error_prob=rate),
                             failover="cxl", **size_kw)
        modes = (("retry_on", rp),) if smoke \
            else (("retry_off", None), ("retry_on", rp))
        for tag, retry in modes:
            cfg = AMU.derive(far=regs, retry=retry)
            with AmuSession(cfg) as s:
                out = s.run("paged_kv_serve", **serve_kw)
            # the port's sync_fallback keeps the fold correct even when
            # the AMI plane reports final failures
            assert out.verified
            rows.append((
                f"faults/serve/err{rate}/{tag}", out.us,
                f"avail={out.availability:.4f},"
                f"p99={out.req_p99_us:.1f},p999={out.req_p999_us:.1f},"
                f"faults={out.faults_injected},retries={out.retries},"
                f"failovers={out.failovers}"))

    # --- serving through a mid-run outage of increasing width (nightly)
    widths = [] if smoke else [20_000.0, 60_000.0]
    for width in widths:
        fm = FaultModel(error_prob=0.01,
                        flaps=(LinkFlap(20_000.0, width, mode="error"),))
        regs = serve_regions(faults=fm, failover="cxl")
        with AmuSession(AMU.derive(far=regs, retry=rp)) as s:
            out = s.run("paged_kv_serve")
        assert out.verified
        rows.append((
            f"faults/serve/flap{int(width)}/retry_on", out.us,
            f"avail={out.availability:.4f},"
            f"p99={out.req_p99_us:.1f},p999={out.req_p999_us:.1f},"
            f"faults={out.faults_injected},retries={out.retries},"
            f"failovers={out.failovers}"))
    return rows


def rack_scaling(smoke: bool = False) -> List[Row]:
    """Rack-scale sweep: aggregate throughput and per-core fairness vs
    core count over ONE shared far-memory device at fixed link bandwidth
    (the default 64 GB/s flat operating point).

    Homogeneous rows run GUPS on every core (per-core spawned seeds);
    ``agg_gups`` divides total updates by the rack makespan, so it scales
    with cores until the shared link saturates, ``fairness`` is Jain's
    index over per-core GUPS and ``link_occ`` the shared channel's busy
    fraction. Mixed rows colocate GUPS with the paged-KV serving port on
    the same device — the serving cores' p99 under a throughput-hungry
    neighbor is the noisy-neighbor number. Smoke mode shrinks to cores
    {1,4} + one mixed pair; the CI gate floors 4-core scaling (>= 2x) and
    homogeneous fairness (>= 0.9)."""
    from repro.amu import RackSession
    from repro.amu.session import _core_seeds

    rows: List[Row] = []
    counts = [1, 4] if smoke else [1, 2, 4, 8, 16]
    gups_kw = dict(table_words=2048, updates=512, coroutines=64,
                   distinct=True) if smoke else {}
    agg1 = None
    for n in counts:
        with RackSession(AMU.derive(cores=n)) as r:
            rs = r.run("GUPS", **gups_kw)
        assert rs.verified
        if agg1 is None:
            agg1 = rs.aggregate_gups
        rows.append((
            f"rack/GUPS/cores{n}", rs.us,
            f"agg_gups={rs.aggregate_gups:.4f},"
            f"fairness={rs.fairness:.4f},"
            f"min_gups={min(rs.core_gups):.4f},"
            f"max_gups={max(rs.core_gups):.4f},"
            f"scaling_vs_1core={rs.aggregate_gups / agg1:.2f}x,"
            f"link_occ={rs.link_occupancy['far']['occupancy']:.4f}"))

    # --- colocation: half the cores run GUPS, half the paged-KV serving
    # port, over the same shared device (prebuilt ports with the same
    # spawned per-core seeds a homogeneous rack would use)
    serve_kw = dict(requests=64, coroutines=16) if smoke else {}
    for n in ([2] if smoke else [2, 4, 8]):
        seeds = _core_seeds(AMU.seed, n)
        ports = [
            REGISTRY.build("GUPS", seeds[i], **gups_kw) if i < n - n // 2
            else REGISTRY.build("paged_kv_serve", seeds[i], **serve_kw)
            for i in range(n)]
        with RackSession(AMU.derive(cores=n)) as r:
            rs = r.run(ports)
        assert rs.verified
        gups_g = [g for g, s in zip(rs.core_gups, rs.cores)
                  if s.workload == "GUPS"]
        serve_p99 = max(s.req_p99_us for s in rs.cores
                        if s.workload == "paged_kv_serve")
        rows.append((
            f"rack/mixed/cores{n}", rs.us,
            f"agg_gups={rs.aggregate_gups:.4f},"
            f"fairness={rs.fairness:.4f},"
            f"gups_min={min(gups_g):.4f},"
            f"serve_p99={serve_p99:.1f},"
            f"link_occ={rs.link_occupancy['far']['occupancy']:.4f}"))
    return rows


def table5_disambiguation() -> List[Row]:
    """Table 5: fraction of execution time in software disambiguation."""
    rows = []
    for wl in ("HJ", "HT"):
        for L in LATS:
            out = _run(wl, "amu", L, verify=False)
            rows.append((f"table5/{wl}/lat{L}", out["us"],
                         f"disamb_frac={out['disamb_frac']:.4f}"))
    return rows


def headline_claims() -> List[Row]:
    """Abstract's headline numbers vs ours."""
    rows = []
    sp = []
    for wl in WORKLOADS:
        b = _run(wl, "baseline", 1.0)["us"]
        a = _run(wl, "amu", 1.0, verify=False)["us"]
        sp.append(b / a)
    geo = float(np.exp(np.mean(np.log(sp))))
    rows.append(("headline/geomean_speedup_1us", geo,
                 f"paper=2.42,ours={geo:.2f}"))
    b5 = _run("GUPS", "baseline", 5.0)["us"]
    l5 = _run("GUPS", "amu-llvm", 5.0, verify=False)
    rows.append(("headline/gups_llvm_speedup_5us", b5 / l5["us"],
                 f"paper=26.86,ours={b5 / l5['us']:.2f}"))
    rows.append(("headline/gups_llvm_mlp_5us", l5["mlp"],
                 f"paper>130,ours={l5['mlp']:.0f}"))
    return rows


ALL_FIGURES = {
    "fig2": fig2_slowdown,
    "fig3": fig3_group_sensitivity,
    "fig8": fig8_exec_time,
    "fig9": fig9_mlp,
    "fig10": fig10_ipc,
    "fig11": fig11_power,
    "table4": table4_prefetch,
    "table5": table5_disambiguation,
    "tail": tail_latency,
    "serve": serve_latency,
    "faults": fault_tolerance,
    "headline": headline_claims,
}
