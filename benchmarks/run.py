"""Benchmark harness: one function per paper table/figure + kernel micro +
engine-driver throughput + roofline. Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--engine scalar|batched]
                                               [figure ...]
(no args -> everything; roofline rows require results/dryrun.jsonl).
`--engine` picks the timed-engine implementation behind the AMU configs:
"batched" (default; vectorized, fast sweeps) or "scalar" (per-event oracle).
"""
from __future__ import annotations

import sys


def main() -> None:
    # imports here so `-m benchmarks.run fig2` doesn't pay for jax
    import benchmarks.paper_figures as pf
    from benchmarks.kernel_micro import engine_driver, kernel_micro
    from benchmarks.roofline import roofline_rows

    args = sys.argv[1:]
    if "--engine" in args:
        i = args.index("--engine")
        if i + 1 >= len(args) or args[i + 1] not in ("scalar", "batched"):
            print("error: --engine requires a value: scalar | batched",
                  file=sys.stderr)
            raise SystemExit(2)
        pf.ENGINE = args[i + 1]
        del args[i:i + 2]

    suites = dict(pf.ALL_FIGURES)
    suites["kernels"] = kernel_micro
    suites["engine"] = engine_driver
    suites["roofline"] = roofline_rows

    wanted = args or list(suites)
    print("name,us_per_call,derived")
    for name in wanted:
        if name not in suites:
            print(f"# unknown suite {name!r}; known: {sorted(suites)}",
                  file=sys.stderr)
            continue
        for row_name, us, derived in suites[name]():
            print(f'{row_name},{us:.2f},"{derived}"', flush=True)


if __name__ == "__main__":
    main()
