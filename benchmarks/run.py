"""Benchmark harness: one function per paper table/figure + kernel micro +
roofline. Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [figure ...]
(no args -> everything; roofline rows require results/dryrun.jsonl).
"""
from __future__ import annotations

import sys


def main() -> None:
    # imports here so `-m benchmarks.run fig2` doesn't pay for jax
    from benchmarks.paper_figures import ALL_FIGURES
    from benchmarks.kernel_micro import kernel_micro
    from benchmarks.roofline import roofline_rows

    suites = dict(ALL_FIGURES)
    suites["kernels"] = kernel_micro
    suites["roofline"] = roofline_rows

    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in wanted:
        if name not in suites:
            print(f"# unknown suite {name!r}; known: {sorted(suites)}",
                  file=sys.stderr)
            continue
        for row_name, us, derived in suites[name]():
            print(f'{row_name},{us:.2f},"{derived}"', flush=True)


if __name__ == "__main__":
    main()
