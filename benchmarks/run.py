"""Benchmark harness: one function per paper table/figure + kernel micro +
engine-driver throughput + roofline. Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--engine scalar|batched]
                                               [--vector] [--sanitize]
                                               [--smoke] [--list]
                                               [--json PATH]
                                               [--profile PATH] [figure ...]
(no args -> everything; roofline rows require results/dryrun.jsonl;
`--list` prints the sweep names and the registered workloads with their
declared capabilities, then exits).
`--engine` picks the timed-engine implementation behind the AMU configs:
"batched" (default; vectorized, fast sweeps) or "scalar" (per-event oracle).
`--sanitize` arms the runtime AMI protocol sanitizer (shadow-state race/
leak checking; see TESTING.md) on every session the sweeps build — both
via AMU_SANITIZE=1 for suites that construct their own configs and by
deriving the shared config. Observation only: results are bit-identical.
`--vector` runs the AloadVec/AstoreVec (and software-pipelined chase)
workload ports — every workload has one — and adds the vector axis to the
`engine` suite. `--smoke` is the CI regression gate: a shrunken `engine`
suite only, which FAILS (exit 1) if the batched engine or the vector ports
lose their speedup floors. `--json PATH` additionally archives the rows as
JSON (name/us_per_call/derived records) — the nightly job uploads this
artifact. `--profile PATH` wraps the whole run in cProfile and dumps the
stats there (readable with `python -m pstats PATH`), so future host-side
Amdahl ceilings are diagnosable straight from a nightly artifact.
"""
from __future__ import annotations

import json
import os
import sys

# CI floors for --smoke (deliberately below the locally-measured numbers so
# noisy runners don't flake, but well above a real regression). Keyed per
# workload: the zero-copy block ports (STREAM/IS, measured 8-12x) hold a
# higher floor than the request-rate ports; LL guards the software-pipelined
# chase path (measured ~2.2x at K=16).
SMOKE_MIN_BATCHED_SPEEDUP = 2.0     # aload_batch driver vs scalar driver
SMOKE_MIN_VECTOR_SPEEDUP = {        # vector port vs scalar-yield port
    "GUPS": 1.5,
    "STREAM": 2.0,
    "IS": 2.0,
    "LL": 1.5,
}
SMOKE_MIN_VECTOR_DEFAULT = 1.5
# serving: mean per-request latency, AMI plane vs the synchronous
# page-fault baseline (measured ~12x scalar / ~19x vector at the smoke
# sizes; MLP across requests is the whole mechanism, so anything near 1x
# means the arrival/latency plumbing broke)
SMOKE_MIN_SERVE_SPEEDUP = 3.0
# epoch-fused host-throughput floor, two ceilings per flagship row:
#  * `entries` — the engine-entry count is a deterministic model fact, so a
#    ceiling catches the fused loop silently degrading back toward
#    per-command entry granularity (GUPS smoke: 119 fused vs 574 per-command;
#    serve vector: 290 vs ~430);
#  * `us_per_entry` — with the entry count pinned, ceiling-gating wall-µs of
#    driver time per entry bounds total driver time for the row's fixed
#    workload shape. These sit ~4x above the locally-measured values (GUPS
#    fused ~410 µs/entry at ~550 rows/entry, serve vector ~90 µs/entry) so
#    loaded CI runners don't flake.
SMOKE_MAX_US_PER_ENTRY = {
    "engine/GUPS_sched_vector_fused": 1600.0,
    "serve/poisson/ami_vector": 400.0,
}
SMOKE_MAX_ENTRIES = {
    "engine/GUPS_sched_vector_fused": 200,
    "serve/poisson/ami_vector": 360,
}
# fault gates (rows from the `faults` suite, retry-enabled only): GUPS at
# 1% error with retries must stay within 1.5x of its fault-free time
# (retry+failover traffic is modeled, so a blowup means the recovery path
# regressed), and serving availability must hold >= 0.99
SMOKE_MAX_FAULT_SLOWDOWN = 1.5
SMOKE_MIN_AVAILABILITY = 0.99
# rack gates (homogeneous 4-core GUPS row, uncontended link bandwidth):
# aggregate throughput must scale >= 2x over one core (measured ~3.2x —
# below that the arbiter is serializing cores it shouldn't), and Jain
# fairness across identical cores must hold >= 0.9 (measured ~0.997)
SMOKE_MIN_RACK_SCALING = 2.0
SMOKE_MIN_RACK_FAIRNESS = 0.9


def _parse_speedup(derived: str, key: str) -> float:
    for part in derived.split(","):
        if part.startswith(key + "="):
            return float(part.split("=")[1].rstrip("x"))
    return 0.0


def _print_catalog(suites, file=None) -> None:
    """``--list``: every sweep, then every registered workload with its
    declared capabilities (straight from repro.amu.REGISTRY)."""
    from repro.amu import REGISTRY
    print("sweeps:", file=file)
    for name in sorted(suites):
        print(f"  {name}", file=file)
    print("workloads (repro.amu.REGISTRY):", file=file)
    caps = ("vector", "pipelined", "locked", "distinct", "frontier",
            "request_level")
    for name, wd in REGISTRY.items():
        have = ",".join(c for c in caps if getattr(wd, c)) or "-"
        desc = f"  {wd.description}" if wd.description else ""
        print(f"  {name}: {have}{desc}", file=file)


def main() -> None:
    # imports here so `-m benchmarks.run fig2` doesn't pay for jax
    import benchmarks.paper_figures as pf
    from benchmarks.kernel_micro import engine_driver, kernel_micro
    from benchmarks.roofline import roofline_rows

    args = sys.argv[1:]
    if "--engine" in args:
        i = args.index("--engine")
        if i + 1 >= len(args) or args[i + 1] not in ("scalar", "batched"):
            print("error: --engine requires a value: scalar | batched",
                  file=sys.stderr)
            raise SystemExit(2)
        pf.AMU = pf.AMU.derive(engine=args[i + 1])
        del args[i:i + 2]
    if "--vector" in args:
        pf.AMU = pf.AMU.derive(vector=True)
        args.remove("--vector")
    if "--sanitize" in args:
        # env var first: suites that build their own AmuConfig (kernel
        # micro-benchmarks) pick the default up from AMU_SANITIZE
        os.environ["AMU_SANITIZE"] = "1"
        pf.AMU = pf.AMU.derive(sanitize=True)
        args.remove("--sanitize")
    smoke = "--smoke" in args
    if smoke:
        args.remove("--smoke")
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args):
            print("error: --json requires a path", file=sys.stderr)
            raise SystemExit(2)
        json_path = args[i + 1]
        del args[i:i + 2]
    profile_path = None
    if "--profile" in args:
        i = args.index("--profile")
        if i + 1 >= len(args):
            print("error: --profile requires a path", file=sys.stderr)
            raise SystemExit(2)
        profile_path = args[i + 1]
        del args[i:i + 2]
    profiler = None
    if profile_path:
        import cProfile
        profiler = cProfile.Profile()
        profiler.enable()

    suites = dict(pf.ALL_FIGURES)
    suites["kernels"] = kernel_micro
    suites["engine"] = lambda: engine_driver(smoke=smoke)
    suites["serve"] = lambda: pf.serve_latency(smoke=smoke)
    suites["faults"] = lambda: pf.fault_tolerance(smoke=smoke)
    suites["rack"] = lambda: pf.rack_scaling(smoke=smoke)
    suites["roofline"] = roofline_rows

    if "--list" in args:
        _print_catalog(suites)
        return

    # smoke mode: the (shrunken) engine-driver throughput, serving,
    # fault-injection and rack suites always run, so the regression gates
    # below can never be vacuously green
    if smoke:
        always = ("engine", "serve", "faults", "rack")
        wanted = list(always) + [a for a in args if a not in always]
    else:
        wanted = args or list(suites)
    collected = []
    print("name,us_per_call,derived")
    for name in wanted:
        if name not in suites:
            print(f"# unknown suite {name!r}; known sweeps and workloads:",
                  file=sys.stderr)
            _print_catalog(suites, file=sys.stderr)
            continue
        for row_name, us, derived in suites[name]():
            collected.append({"name": row_name, "us_per_call": us,
                              "derived": derived})
            print(f'{row_name},{us:.2f},"{derived}"', flush=True)

    if profiler is not None:
        profiler.disable()
        profiler.dump_stats(profile_path)
        print(f"# wrote cProfile stats to {profile_path} "
              f"(python -m pstats {profile_path})", file=sys.stderr)

    if json_path:
        with open(json_path, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"# wrote {len(collected)} rows to {json_path}",
              file=sys.stderr)

    if smoke:
        failures = []
        for row in collected:
            sp = _parse_speedup(row["derived"], "speedup_vs_scalar")
            if sp and sp < SMOKE_MIN_BATCHED_SPEEDUP:
                failures.append(f"{row['name']}: batched/scalar {sp:.2f}x "
                                f"< {SMOKE_MIN_BATCHED_SPEEDUP}x")
            sp = _parse_speedup(row["derived"], "speedup_vs_scalar_yield")
            wl = row["name"].split("/")[-1].split("_")[0]
            floor = SMOKE_MIN_VECTOR_SPEEDUP.get(wl, SMOKE_MIN_VECTOR_DEFAULT)
            if sp and sp < floor:
                failures.append(f"{row['name']}: vector/scalar-yield "
                                f"{sp:.2f}x < {floor}x")
            sp = _parse_speedup(row["derived"], "ami_vs_sync")
            if sp and sp < SMOKE_MIN_SERVE_SPEEDUP:
                failures.append(f"{row['name']}: serving AMI/page-fault "
                                f"{sp:.2f}x < {SMOKE_MIN_SERVE_SPEEDUP}x")
            ceil = SMOKE_MAX_US_PER_ENTRY.get(row["name"])
            if ceil is not None:
                upe = _parse_speedup(row["derived"], "us_per_entry")
                if not upe or upe > ceil:
                    failures.append(f"{row['name']}: fused driver "
                                    f"{upe:.1f} µs/engine-entry > {ceil}")
                ents = _parse_speedup(row["derived"], "entries")
                if not ents or ents > SMOKE_MAX_ENTRIES[row["name"]]:
                    failures.append(
                        f"{row['name']}: {ents:.0f} engine entries > "
                        f"{SMOKE_MAX_ENTRIES[row['name']]} — epoch fusion "
                        f"degraded toward per-command granularity")
            if row["name"] == "rack/GUPS/cores4":
                sc = _parse_speedup(row["derived"], "scaling_vs_1core")
                if sc < SMOKE_MIN_RACK_SCALING:
                    failures.append(
                        f"{row['name']}: 4-core aggregate scaling "
                        f"{sc:.2f}x < {SMOKE_MIN_RACK_SCALING}x over one "
                        f"core at uncontended bandwidth")
                fa = _parse_speedup(row["derived"], "fairness")
                if fa < SMOKE_MIN_RACK_FAIRNESS:
                    failures.append(
                        f"{row['name']}: homogeneous Jain fairness "
                        f"{fa:.4f} < {SMOKE_MIN_RACK_FAIRNESS}")
            if row["name"].startswith("faults/") \
                    and row["name"].endswith("/retry_on"):
                sp = _parse_speedup(row["derived"], "vs_clean")
                if sp and sp > SMOKE_MAX_FAULT_SLOWDOWN:
                    failures.append(
                        f"{row['name']}: faulty/fault-free {sp:.2f}x > "
                        f"{SMOKE_MAX_FAULT_SLOWDOWN}x with retries on")
                av = _parse_speedup(row["derived"], "avail")
                if av and av < SMOKE_MIN_AVAILABILITY:
                    failures.append(
                        f"{row['name']}: availability {av:.4f} < "
                        f"{SMOKE_MIN_AVAILABILITY} with retries on")
        if failures:
            print("SMOKE FAIL: driver-throughput regression:",
                  file=sys.stderr)
            for msg in failures:
                print(f"  {msg}", file=sys.stderr)
            raise SystemExit(1)
        print("# smoke: driver-throughput floors held", file=sys.stderr)


if __name__ == "__main__":
    main()
