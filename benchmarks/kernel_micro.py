"""Kernel micro-benchmarks (interpret mode on CPU: correctness-shaped timing
only; real numbers come from the TPU target). Reports us/call plus the
derived achieved-bytes/flops so the TPU roofline expectation is visible."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

Row = Tuple[str, float, str]


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_micro() -> List[Row]:
    rng = np.random.default_rng(0)
    rows: List[Row] = []
    # GUPS-gather (the paper's flagship random-access pattern)
    table = jnp.array(rng.standard_normal((4096, 128)), jnp.float32)
    idx = jnp.array(rng.integers(0, 4096, 1024), jnp.int32)
    us = _time(lambda: ops.gather(table, idx, block_m=256, num_slots=8))
    moved = 1024 * 128 * 4 * 2
    rows.append(("kernel/async_gather_1k_rows", us,
                 f"bytes={moved},slots=8"))
    # GUPS-update
    upd = jnp.array(rng.standard_normal((1024, 128)), jnp.float32)
    us = _time(lambda: ops.scatter_update(table, idx, upd, block_m=256,
                                          num_slots=8))
    rows.append(("kernel/async_scatter_1k_rows", us,
                 f"bytes={moved * 2},slots=8"))
    # STREAM triad
    b = jnp.array(rng.standard_normal(1 << 16), jnp.float32)
    c = jnp.array(rng.standard_normal(1 << 16), jnp.float32)
    us = _time(lambda: ops.triad(b, c, 3.0, block=512))
    rows.append(("kernel/stream_triad_64k", us,
                 f"bytes={3 * (1 << 16) * 4}"))
    # flash attention prefill block
    q = jnp.array(rng.standard_normal((1, 512, 8, 64)), jnp.bfloat16)
    k = jnp.array(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    v = jnp.array(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    us = _time(lambda: ops.flash_attention(q, k, v, causal=True))
    flops = 4 * 512 * 512 * 8 * 64
    rows.append(("kernel/flash_attention_512", us, f"flops={flops}"))
    # paged decode attention
    q1 = jnp.array(rng.standard_normal((4, 8, 64)), jnp.float32)
    kc = jnp.array(rng.standard_normal((4, 2048, 2, 64)), jnp.float32)
    vc = jnp.array(rng.standard_normal((4, 2048, 2, 64)), jnp.float32)
    lens = jnp.array([2048, 1024, 512, 2048], jnp.int32)
    us = _time(lambda: ops.paged_attention(q1, kc, vc, lens, page=512))
    rows.append(("kernel/paged_attention_2k_kv", us,
                 f"kv_bytes={4 * 2048 * 2 * 64 * 4 * 2}"))
    return rows
