"""Kernel micro-benchmarks (interpret mode on CPU: correctness-shaped timing
only; real numbers come from the TPU target). Reports us/call plus the
derived achieved-bytes/flops so the TPU roofline expectation is visible.

Also hosts the timed-engine *driver throughput* micro (`engine_driver`):
host-side requests retired per second through the scalar oracle vs the
vectorized batched engine, which is what bounds how large a latency x
queue-depth paper sweep is tractable on CPU."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


# =========================================================================
# Timed-engine driver throughput: scalar oracle vs batched engine
# =========================================================================
def _drive_engine(kind: str, n_requests: int, qlen: int,
                  latency_us: float = 1.0) -> float:
    """Keep the request queue full for `n_requests` loads against the timed
    far-memory model, stepping time in latency-sized epochs; returns
    requests retired per wall-clock second."""
    from repro.configs.base import EngineConfig
    from repro.core.engine import make_engine
    from repro.core.farmem import FarMemoryConfig, FarMemoryModel

    far = FarMemoryModel(FarMemoryConfig.from_latency_us(latency_us))
    eng = make_engine(kind, EngineConfig(queue_length=qlen, granularity=8),
                      far)
    epoch = far.config.base_latency_cycles
    rng = np.random.default_rng(0)
    addrs = rng.integers(0, 4096, size=n_requests) * 8
    zeros = np.zeros(qlen, np.int64)
    sizes = np.full(qlen, 8, np.int64)
    t0 = time.perf_counter()
    issued = retired = 0
    now = 0.0
    while retired < n_requests:
        k = min(qlen - eng.active_requests, n_requests - issued)
        if k:
            if kind == "batched":
                eng.aload_batch(zeros[:k], addrs[issued:issued + k],
                                sizes[:k])
            else:
                for i in range(k):
                    eng.aload(0, int(addrs[issued + i]), 8)
            issued += k
        now += epoch
        eng.advance(now)
        if kind == "batched":
            retired += len(eng.getfin_all())
        else:
            while eng.getfin():
                retired += 1
    return n_requests / (time.perf_counter() - t0)


# Per-port build kwargs for the scheduler-stack axis. `scale` applies to
# both ports (same problem); `vec` shapes only the vector/pipelined port
# (chunk widths and coroutine counts are port properties, not workload
# size). Chase ports (LL/Redis) run their software-pipelined variant.
_PORT_SCALE = {
    "GUPS": dict(table_words=1 << 17),
    "STREAM": dict(n=1 << 18),
    "IS": dict(n_keys=1 << 18),
    "LL": dict(lookups=512, coroutines=64),
}
_PORT_VEC = {
    "GUPS": dict(vec_chunk=64),
    "STREAM": dict(vec_chunk=64, coroutines=2),
    "IS": dict(vec_chunk=64, coroutines=4),
    "HPCG": {},
    "LL": dict(pipeline_k=16),
    "Redis": dict(pipeline_k=16),
}


def _drive_workload_port(wl: str, vector: bool, updates: int,
                         latency_us: float = 1.0, scheduler: str = "auto"):
    """Run a workload port through the full scheduler + batched-engine
    stack; returns ``(req_per_s, RunStats)`` — far-memory requests retired
    per wall-clock second plus the run's host-side observability counters
    (engine entries, rows per entry, wall-µs per entry). This is the
    host-side throughput that bounds paper sweeps — `vector=True` runs
    the AloadVec/AstoreVec (or pipelined-chase) port, `vector=False` PR 1's
    scalar-yield port; `scheduler="batched"` pins the per-command loop,
    the `"auto"` default takes the epoch-fused loop."""
    from repro.amu import REGISTRY, AmuConfig, AmuSession

    kw = dict(_PORT_SCALE.get(wl, {}))
    if wl == "GUPS":
        kw["updates"] = updates
    if vector:
        kw.update(vector=True, **_PORT_VEC.get(wl, {}))
    inst = REGISTRY.build(wl, 0, **kw)
    session = AmuSession(AmuConfig(engine="batched", scheduler=scheduler,
                                   latency_us=latency_us, verify=False))
    session.prepare(inst)       # build + stack construction outside timing
    t0 = time.perf_counter()
    stats = session.execute()
    dt = time.perf_counter() - t0
    assert inst.verify(session.engine.mem)
    return stats.requests / dt, stats


def _entry_counters(stats) -> str:
    """Derived-string fragment for the host-side observability counters."""
    return (f"entries={stats.engine_entries},"
            f"rows_per_entry={stats.rows_per_entry:.1f},"
            f"us_per_entry={stats.us_per_entry:.1f}")


def engine_driver(n_requests: int = 100_000, smoke: bool = False) -> List[Row]:
    rows: List[Row] = []
    if smoke:
        n_requests = 20_000
    for qlen in ((256,) if smoke else (256, 1024)):
        scalar = _drive_engine("scalar", n_requests, qlen)
        batched = _drive_engine("batched", n_requests, qlen)
        rows.append((f"engine/scalar_driver_q{qlen}", 1e6 / scalar,
                     f"req_per_s={scalar:.0f}"))
        rows.append((f"engine/batched_driver_q{qlen}", 1e6 / batched,
                     f"req_per_s={batched:.0f},"
                     f"speedup_vs_scalar={batched / scalar:.2f}x"))
    # vector-command axis: scalar-yield vs AloadVec/pipelined ports through
    # the full scheduler stack (GUPS scaled up so fixed costs don't mask the
    # ratio). The smoke set keeps one representative per port family the CI
    # gate holds a floor for: GUPS (vector RMW), STREAM/IS (zero-copy block
    # ports), LL (pipelined chase). Each vector port runs twice — the
    # per-command BatchScheduler (`_sched_vector`, comparable to earlier
    # sweeps) and the epoch-fused loop (`_sched_vector_fused`, one engine
    # entry per epoch) — so the fusion win (entry collapse, fused_vs_percmd
    # speedup, µs/entry) is visible per workload.
    updates = 16_384 if smoke else 65_536
    wls = (("GUPS", "STREAM", "IS", "LL") if smoke
           else ("GUPS", "STREAM", "IS", "HPCG", "LL", "Redis"))
    for wl in wls:
        s, s_st = _drive_workload_port(wl, vector=False, updates=updates)
        v, v_st = _drive_workload_port(wl, vector=True, updates=updates,
                                       scheduler="batched")
        f, f_st = _drive_workload_port(wl, vector=True, updates=updates)
        rows.append((f"engine/{wl}_sched_scalar_yield", 1e6 / s,
                     f"req_per_s={s:.0f},{_entry_counters(s_st)}"))
        rows.append((f"engine/{wl}_sched_vector", 1e6 / v,
                     f"req_per_s={v:.0f},"
                     f"speedup_vs_scalar_yield={v / s:.2f}x,"
                     f"{_entry_counters(v_st)}"))
        rows.append((f"engine/{wl}_sched_vector_fused", 1e6 / f,
                     f"req_per_s={f:.0f},"
                     f"speedup_vs_scalar_yield={f / s:.2f}x,"
                     f"fused_vs_percmd={f / v:.2f}x,"
                     f"{_entry_counters(f_st)}"))
    return rows


def _time(fn, *args, reps=3) -> float:
    import jax
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_micro() -> List[Row]:
    # jax only needed for the Pallas kernel rows, not the engine driver
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows: List[Row] = []
    # GUPS-gather (the paper's flagship random-access pattern)
    table = jnp.array(rng.standard_normal((4096, 128)), jnp.float32)
    idx = jnp.array(rng.integers(0, 4096, 1024), jnp.int32)
    us = _time(lambda: ops.gather(table, idx, block_m=256, num_slots=8))
    moved = 1024 * 128 * 4 * 2
    rows.append(("kernel/async_gather_1k_rows", us,
                 f"bytes={moved},slots=8"))
    # GUPS-update
    upd = jnp.array(rng.standard_normal((1024, 128)), jnp.float32)
    us = _time(lambda: ops.scatter_update(table, idx, upd, block_m=256,
                                          num_slots=8))
    rows.append(("kernel/async_scatter_1k_rows", us,
                 f"bytes={moved * 2},slots=8"))
    # STREAM triad
    b = jnp.array(rng.standard_normal(1 << 16), jnp.float32)
    c = jnp.array(rng.standard_normal(1 << 16), jnp.float32)
    us = _time(lambda: ops.triad(b, c, 3.0, block=512))
    rows.append(("kernel/stream_triad_64k", us,
                 f"bytes={3 * (1 << 16) * 4}"))
    # flash attention prefill block
    q = jnp.array(rng.standard_normal((1, 512, 8, 64)), jnp.bfloat16)
    k = jnp.array(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    v = jnp.array(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    us = _time(lambda: ops.flash_attention(q, k, v, causal=True))
    flops = 4 * 512 * 512 * 8 * 64
    rows.append(("kernel/flash_attention_512", us, f"flops={flops}"))
    # paged decode attention
    q1 = jnp.array(rng.standard_normal((4, 8, 64)), jnp.float32)
    kc = jnp.array(rng.standard_normal((4, 2048, 2, 64)), jnp.float32)
    vc = jnp.array(rng.standard_normal((4, 2048, 2, 64)), jnp.float32)
    lens = jnp.array([2048, 1024, 512, 2048], jnp.int32)
    us = _time(lambda: ops.paged_attention(q1, kc, vc, lens, page=512))
    rows.append(("kernel/paged_attention_2k_kv", us,
                 f"kv_bytes={4 * 2048 * 2 * 64 * 4 * 2}"))
    return rows
